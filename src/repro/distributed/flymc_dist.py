"""Distributed FlyMC: the paper's algorithm sharded across a pod.

Mapping (DESIGN.md §5):
  * data rows sharded over the data axes (and ``pod`` for multi-pod) —
    each shard owns a slice of x, the z-partition, and the δ cache;
  * bound sufficient statistics psum'd ONCE at setup — the collapsed bound
    product stays O(D²) replicated work per step (zero per-step collective
    cost for the bound term, the paper's key property at pod scale);
  * per θ-proposal, one scalar psum of shard-local bright log-pseudo-
    likelihood sums — the minimum communication any exact method needs;
  * z-updates are embarrassingly parallel given θ (shard-local data), with
    per-shard independent RNG (keys folded with the shard index);
  * per-shard bright capacities bound straggler skew: no shard ever does
    data-dependent work beyond C rows (the host grows C globally on
    overflow, exactly as in the single-device chain);
  * streaming collectors (:mod:`repro.api.collectors`) compose for free:
    the sharded step emits θ and StepStats replicated (``out_specs PS()``,
    stats psum'd in-step), so the driver's collector updates run on
    replicated values and the carries stay replicated — online moments,
    split-R̂, and exact query accounting at pod scale cost zero extra
    collectives and no O(iterations) memory.

The collective contract (statically enforced by
``repro.analysis.collectives`` — the ``dist.step`` registry entry pins
these counts exactly; regressions fail the static-analysis CI lane):

===================  ======================================================
psum × 4 per step    1 θ-proposal (the scalar bright log-L̃ sum — the
                     paper's "one scalar reduction per proposal"),
                     1 post-z sampler refresh (same scalar, at the new
                     bright set), 2 StepStats reductions (n_bright,
                     lik_queries) so the driver sees global counts
pmax × 1 per step    the scalar overflow flag — every shard must agree on
                     capacity growth or the re-run protocol diverges
axis_index × 1       per-shard z-key fold (zero wire bytes: it lowers to
                     partition-id) — what makes shard RNG independent
z-phase              ZERO collectives, including inside the z-update scan
                     body: brightness is per-datum, so z-moves are
                     shard-local at any mesh size
===================  ======================================================

Every ``shard_map`` below passes ``check_vma=False`` (jax's own
replication checker off — it rewrites the jaxpr and slows tracing), which
means a ``PS()`` out-spec is TRUSTED, not checked: jax silently installs
shard 0's value everywhere. The replication-consistency rule in
``repro.analysis.collectives`` re-proves every replicated output from the
dataflow instead; per-shard quantities (the bright count ``num``) are
sharded as length-1 rows so no shard-varying value ever crosses a ``PS()``
boundary.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.core import bounds as bounds_lib
from repro.core import brightness, flymc, samplers
from repro.core.bounds import GLMData


def shard_data(data: GLMData, mesh) -> GLMData:
    """Place a host GLMData onto the mesh, rows sharded over all data axes."""
    axes = tuple(mesh.axis_names)
    sharding = NamedSharding(mesh, PS(axes))
    return jax.tree.map(lambda a: jax.device_put(a, sharding), data)


def _state_pspecs(axes):
    # Replicated leaves (PS()) are values every shard provably computes
    # identically: θ/lp/grad come out of the psum'd proposal, log_step
    # adapts on the replicated accept_prob, rng/iteration are threaded
    # replicated by the driver. Everything per-datum (partition arr/tab,
    # the δ cache, sampler aux) is row-sharded. The per-shard bright COUNT
    # is sharded too — as a length-1 row per shard (scalars can't shard),
    # packed/unpacked at the shard_map boundary by _pack/_unpack: declaring
    # it PS() would silently broadcast shard 0's count over every shard
    # under check_vma=False (shards disagree on their bright prefix, so
    # z-updates and overflow detection would run against the wrong count).
    row = PS(axes)
    return flymc.FlyMCState(
        sampler=samplers.SamplerState(
            theta=PS(), lp=PS(), grad=PS(), aux=row
        ),
        bright=brightness.BrightState(arr=row, tab=row, num=row),
        delta_full=row,
        log_step=PS(),
        rng=PS(),
        iteration=PS(),
    )


def _pack(state):
    """Lift the shard-local scalar bright count to a (1,) row so shard_map
    can shard it (global shape: one entry per shard)."""
    return state._replace(
        bright=state.bright._replace(num=state.bright.num[None])
    )


def _unpack(state):
    """Drop the (1,) packing back to the scalar the core sampler expects."""
    return state._replace(
        bright=state.bright._replace(num=state.bright.num[0])
    )


def make_dist_flymc(bound, log_prior, mesh, n_global: int, **spec_kw):
    """Build (spec, init_fn, step_fn, stats_fn) for a data-sharded chain.

    ``capacity``/``cand_capacity`` in spec_kw are PER-SHARD. Pass
    ``backend="pallas"`` to route each shard's θ-update through the fused
    bright-GLM kernel (the pallas_call runs shard-local inside shard_map;
    only the scalar log L̃ sum is psum'd, exactly like the jnp path), and
    ``z_backend="fused"`` to stream each shard's z-update through the
    ``kernels/z_update`` candidate kernel + incremental partition updates —
    z-moves are shard-local (per-shard folded keys), so the fused engine
    needs no extra collectives either.
    """
    axes = tuple(mesh.axis_names)
    # mesh.size (not mesh.devices.size): works for AbstractMesh too, so the
    # static-analysis sweep can trace these programs with no devices at all.
    n_shards = mesh.size
    assert n_global % n_shards == 0
    spec = flymc.FlyMCSpec(
        bound=bound, log_prior=log_prior, axis_names=axes, **spec_kw
    )
    data_ps = GLMData(x=PS(axes), t=PS(axes), xi=PS(axes))
    stats_ps = bounds_lib.CollapsedStats(Q=PS(), q=PS(), c=PS())
    state_ps = _state_pspecs(axes)
    stats_out_ps = flymc.StepStats(*([PS()] * 5))

    def _stats_local(data):
        return bounds_lib.psum_stats(bound.suffstats(data), axes)

    # check_vma=False at every call site below: jax's replication checker is
    # skipped for trace speed, so replicated (PS()) outputs are TRUSTED —
    # the repro.analysis.collectives replication rule re-proves each one
    # from the dataflow instead. Here: the stats come out of psum_stats.
    stats_fn = jax.jit(
        jax.shard_map(
            _stats_local, mesh=mesh, in_specs=(data_ps,),
            out_specs=stats_ps, check_vma=False,
        )
    )

    def _init_local(data, stats, theta0, key):
        state, nb, _ = flymc.init_chain(spec, data, stats, theta0, key)
        # nb is the shard-local initial bright count: psum for the global
        # (replicated) number; the per-shard count stays in the state.
        return _pack(state), jax.lax.psum(nb, axes)

    # check_vma=False: replicated outputs are the psum'd nb and the state's
    # PS() leaves (θ/lp/grad from the replicated init, rng/iteration);
    # per-shard leaves (incl. the packed bright count) are row-sharded.
    init_fn = jax.jit(
        jax.shard_map(
            _init_local, mesh=mesh,
            in_specs=(data_ps, stats_ps, PS(), PS()),
            out_specs=(state_ps, PS()),
            check_vma=False,
        )
    )

    def _step_local(data, stats, state):
        new_state, stats_out = flymc.flymc_step(
            spec, data, stats, _unpack(state)
        )
        return _pack(new_state), stats_out

    # check_vma=False: the contract in the module docstring is what makes
    # the PS() outputs sound — θ/lp/grad/accept/log_step derive from the
    # psum'd proposal, StepStats are psum'd/pmax'd in-step — and the
    # dist.step entry point in repro.analysis.registry verifies exactly
    # that (budget: 4 scalar psum + 1 pmax + 1 axis_index, z-phase zero).
    step_fn = jax.jit(
        jax.shard_map(
            _step_local, mesh=mesh,
            in_specs=(data_ps, stats_ps, state_ps),
            out_specs=(state_ps, stats_out_ps),
            check_vma=False,
        )
    )
    return spec, init_fn, step_fn, stats_fn


def _spec_kw_of(spec: flymc.FlyMCSpec) -> dict:
    return {
        f.name: getattr(spec, f.name)
        for f in dataclasses.fields(spec)
        if f.name not in ("bound", "log_prior", "axis_names")
    }


def dist_algorithm(bound, log_prior, mesh, data: GLMData, **spec_kw):
    """A data-sharded FlyMC chain as a repro.api SamplingAlgorithm.

    ``data`` must already be placed on the mesh (see :func:`shard_data`).
    ``spec_kw`` accepts every FlyMCSpec field, including
    ``backend="pallas"`` for the fused θ-update kernel and
    ``z_backend="fused"`` for the streamed z-update engine.
    The returned algorithm plugs into ``repro.api.sample`` — the chunked
    ``lax.scan`` runs over the shard-mapped step, so the whole chunk stays on
    device and capacity growth follows the same chunk-boundary re-run
    protocol as the single-host chain (per-shard capacities doubled
    globally, same replicated RNG keys). ``sample(..., collectors=...)``
    works unchanged: collector carries live outside the shard_map on the
    replicated (θ, psum'd StepStats) outputs, so streamed diagnostics need
    no extra collectives and re-run bitwise on capacity growth.
    """
    from repro.api import SamplingAlgorithm

    n_global = data.x.shape[0]
    # Capacities are PER-SHARD: growth must cap at the shard-local row count,
    # not N — bright_buffer slices the shard-local arr inside shard_map.
    n_local = n_global // mesh.size
    spec, init_fn, step_fn, stats_fn = make_dist_flymc(
        bound, log_prior, mesh, n_global, **spec_kw
    )
    stats = stats_fn(data)
    axes = tuple(mesh.axis_names)

    def init(key, position):
        state, _ = init_fn(data, stats, position, key)
        return state

    def step(key, state):
        return step_fn(data, stats, state._replace(rng=key))

    grown = []  # memoized so the driver's jit cache sees a stable identity

    def grow():
        if not grown:
            grown.append(
                dist_algorithm(
                    bound, log_prior, mesh, data,
                    **_spec_kw_of(flymc._grow(spec, n_local)),
                )
            )
        return grown[0]

    def resize(state):
        return _resize_dist(spec, state, mesh)

    # Replicated "any shard's initial bright set exceeds its capacity" flag,
    # so the driver re-initializes at a grown capacity exactly like the
    # single-host chain (init_chain_state leaves the state truncated).
    # check_vma=False: the single PS() output is sound because the pmax is
    # what replicates it — each shard contributes its OWN bright count
    # (num arrives sharded, (1,) per shard), so a shard-local overflow on
    # any device raises the flag everywhere.
    _overflow_fn = jax.jit(
        jax.shard_map(
            lambda s: jax.lax.pmax(
                (s.bright.num[0] > spec.capacity).astype(jnp.int32), axes
            ).astype(bool),
            mesh=mesh,
            in_specs=(_state_pspecs(axes),),
            out_specs=PS(),
            check_vma=False,
        )
    )

    can_grow = spec.capacity < n_local or spec.cand_capacity < n_local
    return SamplingAlgorithm(
        init=init,
        step=step,
        grow=grow if can_grow else None,
        resize=resize,
        init_overflow=_overflow_fn if can_grow else None,
        default_position=jnp.zeros(data.x.shape[-1]),
        spec=spec,
    )


def chain_fleet(alg, mesh):
    """Shard a SamplingAlgorithm's CHAIN axis across a mesh of devices.

    The complement of :func:`dist_algorithm`: instead of sharding the *data*
    rows of one chain, shard the *chains* of a fleet — each device owns
    ``num_chains / n_devices`` whole chains (data replicated) and advances
    them with the algorithm's chain-batched step (:func:`repro.api.firefly`'s
    dispatches its Pallas kernels as one chain-grid launch per device).
    Chains are independent, so the step needs ZERO cross-chain collectives —
    shard_map here is pure placement, and throughput scales with devices at
    the same marginal cost per chain as single-device batching.

    The returned algorithm plugs into ``repro.api.sample(num_chains=K)``
    unchanged (K must be divisible by the mesh size; shard_map enforces it).
    Capacity growth composes: ``grow()`` re-wraps the grown inner algorithm
    on the same mesh, memoized so the driver's jit cache keys stay stable.
    Use this for fleets of independent chains on replicated data; use
    :func:`dist_algorithm` when one chain's DATA does not fit a device (the
    two compose only as alternatives today, not nested).
    """
    from repro.api import SamplingAlgorithm

    axes = tuple(mesh.axis_names)
    row = PS(axes)  # leading-axis (chain) sharding, as a pytree prefix
    # check_vma=False on all three fleet shard_maps: trivially sound — every
    # in/out spec is chain-sharded (no PS() output exists to mis-replicate)
    # and the bodies contain ZERO collectives, the budget the
    # dist.chain_fleet entry point pins (chains are independent; shard_map
    # here is pure placement).
    step_chains = jax.shard_map(
        alg.batched_step(), mesh=mesh, in_specs=(row, row),
        out_specs=(row, row), check_vma=False,
    )
    init_chains = jax.shard_map(
        alg.batched_init(), mesh=mesh, in_specs=(row, row), out_specs=row,
        check_vma=False,
    )
    # The operand-data form: chains sharded, the dataset REPLICATED as a
    # traced operand (PS() specs) rather than closed over — the fleet's
    # chunk jit then carries no dataset-sized constant, same exactness
    # rationale as the driver's _threads_data path (and what lets the
    # repro.analysis closure-constant rule pass on the fleet entry point).
    step_chains_data = None
    if alg.step_data is not None and alg.data is not None:
        step_chains_data = jax.shard_map(
            jax.vmap(alg.step_data, in_axes=(0, 0, None, None)),
            mesh=mesh, in_specs=(row, row, PS(), PS()),
            out_specs=(row, row), check_vma=False,
        )

    grown = []  # memoized so the driver's jit cache sees a stable identity

    def grow():
        if not grown:
            grown.append(chain_fleet(alg.grow(), mesh))
        return grown[0]

    return SamplingAlgorithm(
        init=alg.init,
        step=alg.step,
        step_chains=step_chains,
        init_chains=init_chains,
        step_data=alg.step_data,
        step_chains_data=step_chains_data,
        data=alg.data,
        stats=alg.stats,
        grow=grow if alg.grow is not None else None,
        resize=alg.resize,
        init_overflow=alg.init_overflow,
        position=alg.position,
        default_position=alg.default_position,
        spec=alg.spec,
    )


def run_dist_chain(
    bound, log_prior, mesh, data: GLMData, theta0, key, num_iters: int,
    **spec_kw,
):
    """Sharded-chain driver (shim over ``repro.api.sample``).

    Returns (thetas, trace, total_queries) like the original host loop, but
    the chain now runs in chunked on-device scans with one host sync per
    chunk instead of ~4 per iteration.
    """
    from repro import api

    data = shard_data(data, mesh)
    alg = dist_algorithm(bound, log_prior, mesh, data, **spec_kw)
    trace = api.sample(alg, key, num_iters, init_position=theta0)
    thetas = list(jax.device_get(trace.theta[0]))
    st = jax.device_get(trace.stats)
    trace_dicts = [
        {
            "n_bright": int(st.n_bright[0, i]),
            "lik_queries": int(st.lik_queries[0, i]),
            "accept_prob": float(st.accept_prob[0, i]),
        }
        for i in range(num_iters)
    ]
    return thetas, trace_dicts, int(jax.device_get(trace.total_queries))


def _resize_dist(spec, state, mesh):
    axes = tuple(mesh.axis_names)
    # check_vma=False: resize is shard-local (pure buffer growth) — every
    # replicated leaf passes through untouched, per-shard leaves stay
    # sharded (the packed bright count crosses the boundary as a row).
    fn = jax.jit(
        jax.shard_map(
            lambda s: _pack(flymc.resize_state(spec, _unpack(s))),
            mesh=mesh,
            in_specs=(_state_pspecs(axes),), out_specs=_state_pspecs(axes),
            check_vma=False,
        )
    )
    return fn(state)
