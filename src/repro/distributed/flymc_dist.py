"""Distributed FlyMC: the paper's algorithm sharded across a pod.

Mapping (DESIGN.md §5):
  * data rows sharded over the data axes (and ``pod`` for multi-pod) —
    each shard owns a slice of x, the z-partition, and the δ cache;
  * bound sufficient statistics psum'd ONCE at setup — the collapsed bound
    product stays O(D²) replicated work per step (zero per-step collective
    cost for the bound term, the paper's key property at pod scale);
  * per θ-proposal, one scalar psum of shard-local bright log-pseudo-
    likelihood sums — the minimum communication any exact method needs;
  * z-updates are embarrassingly parallel given θ (shard-local data), with
    per-shard independent RNG (keys folded with the shard index);
  * per-shard bright capacities bound straggler skew: no shard ever does
    data-dependent work beyond C rows (the host grows C globally on
    overflow, exactly as in the single-device chain).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.core import bounds as bounds_lib
from repro.core import brightness, flymc, samplers
from repro.core.bounds import GLMData


def shard_data(data: GLMData, mesh) -> GLMData:
    """Place a host GLMData onto the mesh, rows sharded over all data axes."""
    axes = tuple(mesh.axis_names)
    sharding = NamedSharding(mesh, PS(axes))
    return jax.tree.map(lambda a: jax.device_put(a, sharding), data)


def _state_pspecs(axes):
    row = PS(axes)
    return flymc.FlyMCState(
        sampler=samplers.SamplerState(
            theta=PS(), lp=PS(), grad=PS(), aux=row
        ),
        bright=brightness.BrightState(arr=row, tab=row, num=PS()),
        delta_full=row,
        log_step=PS(),
        rng=PS(),
        iteration=PS(),
    )


def make_dist_flymc(bound, log_prior, mesh, n_global: int, **spec_kw):
    """Build (spec, init_fn, step_fn, stats_fn) for a data-sharded chain.

    ``capacity``/``cand_capacity`` in spec_kw are PER-SHARD.
    """
    axes = tuple(mesh.axis_names)
    n_shards = mesh.devices.size
    assert n_global % n_shards == 0
    spec = flymc.FlyMCSpec(
        bound=bound, log_prior=log_prior, axis_names=axes, **spec_kw
    )
    data_ps = GLMData(x=PS(axes), t=PS(axes), xi=PS(axes))
    stats_ps = bounds_lib.CollapsedStats(Q=PS(), q=PS(), c=PS())
    state_ps = _state_pspecs(axes)
    stats_out_ps = flymc.StepStats(*([PS()] * 5))

    def _stats_local(data):
        return bounds_lib.psum_stats(bound.suffstats(data), axes)

    stats_fn = jax.jit(
        jax.shard_map(
            _stats_local, mesh=mesh, in_specs=(data_ps,),
            out_specs=stats_ps, check_vma=False,
        )
    )

    def _init_local(data, stats, theta0, key):
        state, nb, _ = flymc.init_chain(spec, data, stats, theta0, key)
        return state, nb

    init_fn = jax.jit(
        jax.shard_map(
            _init_local, mesh=mesh,
            in_specs=(data_ps, stats_ps, PS(), PS()),
            out_specs=(state_ps, PS()),
            check_vma=False,
        )
    )

    step_fn = jax.jit(
        jax.shard_map(
            partial(flymc.flymc_step, spec), mesh=mesh,
            in_specs=(data_ps, stats_ps, state_ps),
            out_specs=(state_ps, stats_out_ps),
            check_vma=False,
        )
    )
    return spec, init_fn, step_fn, stats_fn


def run_dist_chain(
    bound, log_prior, mesh, data: GLMData, theta0, key, num_iters: int,
    **spec_kw,
):
    """Host driver for a sharded chain, with global capacity growth.

    Returns (thetas, trace, total_queries).
    """
    n_global = data.x.shape[0]
    data = shard_data(data, mesh)
    spec, init_fn, step_fn, stats_fn = make_dist_flymc(
        bound, log_prior, mesh, n_global, **spec_kw
    )
    stats = stats_fn(data)
    state, _ = init_fn(data, stats, theta0, key)

    thetas, trace = [], []
    total_q = 0
    for _ in range(num_iters):
        prev = state
        state2, st = step_fn(data, stats, state)
        while bool(jax.device_get(st.overflow)):
            # grow per-shard capacities globally; exact re-run (same keys)
            grown = dataclasses.replace(
                spec,
                capacity=min(2 * spec.capacity, n_global),
                cand_capacity=min(2 * spec.cand_capacity, n_global),
            )
            spec, init_fn, step_fn, stats_fn = make_dist_flymc(
                bound, log_prior, mesh, n_global,
                **{
                    f.name: getattr(grown, f.name)
                    for f in dataclasses.fields(grown)
                    if f.name not in ("bound", "log_prior", "axis_names")
                },
            )
            prev = _resize_dist(spec, prev, mesh)
            state2, st = step_fn(data, stats, prev)
        state = state2
        total_q += int(jax.device_get(st.lik_queries))
        thetas.append(jax.device_get(state.sampler.theta))
        trace.append(
            {
                "n_bright": int(jax.device_get(st.n_bright)),
                "lik_queries": int(jax.device_get(st.lik_queries)),
                "accept_prob": float(jax.device_get(st.accept_prob)),
            }
        )
    return thetas, trace, total_q


def _resize_dist(spec, state, mesh):
    axes = tuple(mesh.axis_names)
    fn = jax.jit(
        jax.shard_map(
            partial(flymc.resize_state, spec), mesh=mesh,
            in_specs=(_state_pspecs(axes),), out_specs=_state_pspecs(axes),
            check_vma=False,
        )
    )
    return fn(state)
