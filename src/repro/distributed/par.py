"""Parallelism primitives shared by every model layer.

All model code is written once against :class:`Par` and runs in two modes:

  * trivial ``Par()`` — no mesh axes; every collective helper is an identity.
    Used by single-device smoke tests and reduced-config examples.
  * sharded ``Par(dp=("pod", "data"), mp="model", ...)`` — inside
    ``shard_map``; helpers lower to jax.lax collectives.

Parameter placement is described per-leaf by :class:`WSpec`:

  * ``tp_dim``    — dimension sharded over the ``model`` axis (stays sharded
    in compute: Megatron column/row parallel, vocab parallel, head parallel,
    expert ff slices).
  * ``fsdp_dim``  — dimension sharded at rest over as many remaining mesh
    axes as divide it (ZeRO-3); all-gathered just-in-time for compute, which
    makes autodiff produce the matching reduce-scatter for gradients.
  * ``sync``      — mesh axes that neither tp nor fsdp cover. The param is
    replicated over them in compute, so gradients need one explicit psum
    (and the global-norm accounting divides by the replica count).

The placement rule is resolved *per architecture* at build time
(:func:`resolve`): e.g. whisper-tiny's d_model=384 cannot shard 512-ways, so
its weights keep ``sync=('model',)`` while qwen1.5-110b shards everything.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Par:
    """Axis context a model function runs under."""

    dp: tuple[str, ...] = ()  # batch/FSDP axes, e.g. ("pod", "data")
    mp: str | None = None  # model axis
    dp_size: int = 1
    mp_size: int = 1

    @property
    def all_axes(self) -> tuple[str, ...]:
        return self.dp + ((self.mp,) if self.mp else ())

    def axis_sizes(self) -> dict[str, int]:
        # dp sizes are aggregate; exact per-axis sizes provided at build.
        raise NotImplementedError


def psum(x, axes):
    if not axes:
        return x
    return jax.lax.psum(x, tuple(axes))


def pmax(x, axes):
    if not axes:
        return x
    return jax.lax.pmax(x, tuple(axes))


def all_gather(x, axes, axis: int):
    """Tiled all-gather along dimension ``axis`` over mesh ``axes``."""
    if not axes:
        return x
    return jax.lax.all_gather(x, tuple(axes), axis=axis, tiled=True)


def reduce_scatter(x, axes, axis: int):
    """Tiled reduce-scatter (psum_scatter) along ``axis`` over ``axes``."""
    if not axes:
        return x
    return jax.lax.psum_scatter(x, tuple(axes), scatter_dimension=axis, tiled=True)


def axis_index(axis: str | None):
    if axis is None:
        return jnp.int32(0)
    return jax.lax.axis_index(axis)


# ---------------------------------------------------------------------------
# Weight placement specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WSpec:
    """Resolved placement of one parameter."""

    shape: tuple[int, ...]  # global logical shape
    dtype: Any
    tp_dim: int | None = None  # dim sharded over `model` in compute
    fsdp_dim: int | None = None  # dim sharded at rest, gathered for compute
    fsdp_axes: tuple[str, ...] = ()
    sync: tuple[str, ...] = ()  # axes needing explicit grad psum
    init: str = "normal"  # normal | zeros | ones | scaled
    init_scale: float = 1.0

    def pspec(self, mp_axis: str | None) -> P:
        """Storage PartitionSpec (for shard_map in_specs / NamedSharding)."""
        entries: list = [None] * len(self.shape)
        if self.tp_dim is not None and mp_axis:
            entries[self.tp_dim] = mp_axis
        if self.fsdp_dim is not None and self.fsdp_axes:
            if entries[self.fsdp_dim] is not None:
                raise ValueError("tp and fsdp on same dim")
            entries[self.fsdp_dim] = self.fsdp_axes
        return P(*entries)

    def replicas(self, mesh_sizes: dict[str, int]) -> int:
        return math.prod(mesh_sizes.get(a, 1) for a in self.sync) or 1

    def local_shape(self, mesh_sizes: dict[str, int], mp_axis: str | None):
        s = list(self.shape)
        if self.tp_dim is not None and mp_axis:
            s[self.tp_dim] //= mesh_sizes.get(mp_axis, 1)
        if self.fsdp_dim is not None:
            s[self.fsdp_dim] //= math.prod(
                mesh_sizes.get(a, 1) for a in self.fsdp_axes
            )
        return tuple(s)


@dataclasses.dataclass(frozen=True)
class WDef:
    """Pre-resolution parameter definition emitted by layer builders."""

    shape: tuple[int, ...]
    tp_dim: int | None = None
    fsdp_pref: tuple[int, ...] = (0,)  # candidate fsdp dims, in order
    init: str = "normal"
    init_scale: float = 1.0
    dtype: Any = jnp.float32


def resolve(
    defn: WDef,
    mesh_sizes: dict[str, int],
    mp_axis: str | None,
    exclude_fsdp: tuple[str, ...] = (),
) -> WSpec:
    """Pick fsdp axes for a param given the mesh (largest dividing subset).

    ``exclude_fsdp`` removes axes from sharding candidates — used to keep
    parameters replicated across the DCN (pod) axis so the pod gradient
    reduction can be compressed (optim.compression); those axes land in
    ``sync`` instead.
    """
    axes_order = [
        a for a in ("pod", "data")
        if a in mesh_sizes and a not in exclude_fsdp
    ]
    if defn.tp_dim is None and mp_axis in mesh_sizes:
        axes_order = axes_order + [mp_axis]
    # Candidate axis sets: contiguous windows of the axis order, tried from
    # the largest total shard count down (ties prefer dropping 'pod' first —
    # DCN is the slowest place to put an fsdp gather).
    candidates: list[tuple[str, ...]] = []
    n = len(axes_order)
    for width in range(n, 0, -1):
        for start in range(n - width, -1, -1):
            combo = tuple(axes_order[start : start + width])
            if combo not in candidates:
                candidates.append(combo)
    candidates.sort(
        key=lambda c: math.prod(mesh_sizes[a] for a in c) if c else 1,
        reverse=True,
    )
    candidates.append(())

    tp_frac = 1
    best: tuple[tuple[str, ...], int | None] = ((), None)
    for combo in candidates:
        size = math.prod(mesh_sizes[a] for a in combo) if combo else 1
        for dim in defn.fsdp_pref:
            d = defn.shape[dim]
            if defn.tp_dim == dim:
                continue
            if defn.tp_dim is not None and mp_axis:
                pass  # tp dim already excluded
            if d % size == 0:
                best = (combo, dim if combo else None)
                break
        if best[0]:
            break
    fsdp_axes, fsdp_dim = best
    covered = set(fsdp_axes)
    if defn.tp_dim is not None and mp_axis:
        covered.add(mp_axis)
    sync = tuple(a for a in mesh_sizes if a not in covered)
    del tp_frac
    return WSpec(
        shape=defn.shape,
        dtype=defn.dtype,
        tp_dim=defn.tp_dim if mp_axis else None,
        fsdp_dim=fsdp_dim,
        fsdp_axes=fsdp_axes,
        sync=sync,
        init=defn.init,
        init_scale=defn.init_scale,
    )


def gather_param(w: jax.Array, spec: WSpec, compute_dtype=jnp.bfloat16):
    """Cast → all-gather the fsdp axes (JIT weight gather, ZeRO-3).

    Casting *before* the gather halves the collective bytes; the cast's
    transpose returns gradients to f32 after the (bf16) reduce-scatter.
    """
    w = w.astype(compute_dtype)
    if spec.fsdp_dim is None or not spec.fsdp_axes:
        return w
    return all_gather(w, spec.fsdp_axes, axis=spec.fsdp_dim)


def sync_grads(grads: dict, specs: dict, tree_path=()):
    """Explicit psum for grads of sync-replicated params (leaf-wise)."""

    def walk(g, s):
        if isinstance(g, dict):
            return {k: walk(g[k], s[k]) for k in g}
        if s.sync:
            return psum(g, s.sync)
        return g

    return walk(grads, specs)


# ---------------------------------------------------------------------------
# Parameter initialization from spec trees
# ---------------------------------------------------------------------------


def init_param(key: jax.Array, spec: WSpec, local: bool, mesh_sizes, mp_axis):
    shape = spec.local_shape(mesh_sizes, mp_axis) if local else spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(shape, spec.dtype)
    if spec.init == "const":
        return jnp.full(shape, spec.init_scale, spec.dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = spec.init_scale / math.sqrt(max(fan_in, 1))
    return (scale * jax.random.normal(key, shape)).astype(spec.dtype)


def init_tree(key: jax.Array, specs: dict, local=False, mesh_sizes=None, mp_axis=None):
    """Initialize a (possibly nested) dict of params from WSpecs."""
    mesh_sizes = mesh_sizes or {}
    leaves = []

    def collect(s, path):
        if isinstance(s, dict):
            for k in sorted(s):
                collect(s[k], path + (k,))
        else:
            leaves.append((path, s))

    collect(specs, ())
    keys = jax.random.split(key, max(len(leaves), 1))
    out: dict = {}
    for (path, spec), k in zip(leaves, keys):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = init_param(k, spec, local, mesh_sizes, mp_axis)
    return out


def spec_tree_to_pspecs(specs: dict, mp_axis: str | None):
    def walk(s):
        if isinstance(s, dict):
            return {k: walk(v) for k, v in s.items()}
        return s.pspec(mp_axis)

    return walk(specs)


def abstract_tree(specs: dict):
    """ShapeDtypeStructs of the *global* params (for dry-run lowering)."""

    def walk(s):
        if isinstance(s, dict):
            return {k: walk(v) for k, v in s.items()}
        return jax.ShapeDtypeStruct(s.shape, s.dtype)

    return walk(specs)
