"""Distributed runtime: mesh conventions, parallel primitives, sharded FlyMC."""
