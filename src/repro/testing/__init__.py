"""repro.testing — fault-injection tooling for the serve stack.

:mod:`repro.testing.chaos` drives the sampling service through seeded,
fully deterministic fault schedules (device loss, chunk crashes, NaN
poisoning, checkpoint corruption, kill-points mid-save, stragglers) and
verifies the exactness contract under fire: every surviving job's committed
trajectory bitwise identical to its fault-free run, every faulted job's
results a bitwise clean prefix, and no corrupt checkpoint ever restored
silently.
"""

from repro.testing.chaos import (
    ChaosError,
    ChaosHarness,
    ChaosReport,
    Fault,
    InjectedKill,
    run_schedule,
    schedule,
)

__all__ = [
    "ChaosError",
    "ChaosHarness",
    "ChaosReport",
    "Fault",
    "InjectedKill",
    "run_schedule",
    "schedule",
]
