"""Deterministic fault-injection harness for the sampling service.

The serve stack's recovery story rests on one theorem-shaped fact: chunks
are bitwise replayable (per-iteration keys derive from the states' own
iteration counters), so *exact* recovery is always available — re-run from
the last committed boundary and you ARE on the fault-free trajectory, not
an approximation of it. This module turns that claim into an executable
check. A seeded :func:`schedule` places faults at service-step boundaries;
:class:`ChaosHarness` injects them through the runtime's real seams (the
engine chunk path, the lane trees, the checkpoint write hooks, the service
clock); :func:`run_schedule` drives a full service run under the schedule
and verifies, job by job:

  * every **surviving** job's results are bitwise identical to a fault-free
    service run's (which PR 6's tests pin bitwise to the solo
    ``api.sample`` run — transitively, chaos survivors match solo);
  * every **quarantined/failed** job holds a bitwise *clean prefix* of its
    fault-free trajectory — the poisoned or crashed chunk never leaked into
    a committed result;
  * a job that retires twice (a crash rewound it to a checkpoint and it
    replayed) produced **identical results both times**;
  * **no corrupt checkpoint is ever restored silently**: every restart
    after checkpoint corruption either lands on an older intact step with a
    ``checkpoint_fallback`` fault event, or refuses loudly.

Faults injected (kind → mechanism):

=================  =======================================================
chunk_error        arm a group's ``run_chunk`` to raise once → the
                   service's bounded retry replays the chunk
nan_theta          overwrite one running job's θ-lane with NaN on device
nan_data           flip one float of one job's dataset lane to NaN
device_loss        ``handle_device_loss(0 or 1)``; recovery is scheduled
                   automatically two steps later
straggle           slow one group's fake wall-clock 10× → StragglerMonitor
                   escalation
kill_<point>       arm the checkpointer kill hook and force a save; the
                   simulated process death is followed by a cold restart
                   from disk (sweep recovery + verified restore)
ckpt_bitflip       flip one bit of one leaf file of the newest checkpoint,
                   then cold-restart
ckpt_truncate      truncate a leaf file of the newest checkpoint, then
                   cold-restart
ckpt_torn          truncate ``manifest.json`` mid-byte (a torn write),
                   then cold-restart
=================  =======================================================

Everything is seeded and host-deterministic: ``random.Random(seed)`` picks
kinds, steps and targets; the fake clock replaces wall time; backoff sleeps
are disabled. Run the suite from the CLI::

    python -m repro.testing.chaos --seeds 0 1 2 3
"""

from __future__ import annotations

import dataclasses
import random
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data import logistic_data, softmax_data
from repro.serve import Job, RetryPolicy, Service, TerminationPolicy
from repro.serve import faults as faults_lib
from repro.serve.results import JobResult, JobStatus


class InjectedKill(BaseException):
    """Simulated process death at a checkpoint kill point. Derives from
    BaseException on purpose: nothing in the runtime may ``except
    Exception`` it away — a dead process cannot be retried in-line, only
    restarted from disk."""

    def __init__(self, point: str):
        super().__init__(f"injected kill at checkpoint point {point!r}")
        self.point = point


class ChaosError(RuntimeError):
    """The injected chunk-execution failure (stands in for an XLA launch
    error, a preempted device, an OOM — anything transient)."""


# Checkpoint-corruption kinds and the checkpointer's kill points.
_CKPT_KINDS = ("ckpt_bitflip", "ckpt_truncate", "ckpt_torn")
_KILL_POINTS = ("begin", "leaves_written", "manifest_written",
                "pre_rename", "renamed")

ALL_KINDS = (
    "chunk_error", "nan_theta", "nan_data", "device_loss", "straggle",
) + _CKPT_KINDS + tuple(f"kill_{p}" for p in _KILL_POINTS)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled injection: fire ``kind`` just before harness step
    ``step``. ``arg`` is kind-specific (device count for device_loss)."""

    kind: str
    step: int
    arg: int | None = None


def schedule(seed: int, *, n_steps: int = 12, n_faults: int = 5,
             kinds: tuple = ALL_KINDS) -> list[Fault]:
    """A deterministic fault schedule: ``n_faults`` draws over ``kinds``,
    placed at steps [2, n_steps) — step 0/1 stay clean so the first
    checkpoints exist before anything attacks them. Same seed → same
    schedule, byte for byte."""
    rng = random.Random(seed)
    out = []
    for _ in range(n_faults):
        kind = rng.choice(list(kinds))
        step = rng.randrange(2, max(3, n_steps))
        arg = rng.choice([0, 1]) if kind == "device_loss" else None
        out.append(Fault(kind=kind, step=step, arg=arg))
    return sorted(out, key=lambda f: f.step)


class ChaosHarness:
    """Instruments one live Service for fault injection.

    The seams are the runtime's own: ``Scheduler._engine_for`` is the single
    engine-construction point (so every engine's ``run_chunk`` gets wrapped,
    including engines born after a repack), ``Service._clock`` /
    ``Service._sleep`` virtualize time, and ``Checkpointer._kill_hook`` is
    the checkpointer's own crash-simulation hook. Nothing here reaches into
    jitted code — injected faults land between chunks, exactly where real
    host-visible faults land.
    """

    def __init__(self, svc: Service, rng: random.Random):
        self.svc = svc
        self.rng = rng
        self._armed_errors: dict[str, int] = {}   # label -> raises pending
        self._slow: dict[str, float] = {}          # label -> time factor
        self._faketime = 0.0
        self.raised = 0          # armed chunk errors that actually raised
        self.poisoned: list[str] = []  # job ids NaN'd since last drain
        svc._clock = lambda: self._faketime
        svc._sleep = lambda s: None  # no real sleeping under chaos
        orig = svc.scheduler._engine_for

        def patched(job, capacity=None, cand_capacity=None):
            eng = orig(job, capacity=capacity, cand_capacity=cand_capacity)
            self._instrument(eng)
            return eng

        svc.scheduler._engine_for = patched
        for eng in svc.scheduler.engines.values():
            self._instrument(eng)

    def _instrument(self, eng):
        if getattr(eng, "_chaos_wrapped", False):
            return
        label = faults_lib.group_label(eng.group_key)
        real = eng.run_chunk

        def wrapped(chunk_size):
            if self._armed_errors.get(label, 0) > 0:
                self._armed_errors[label] -= 1
                self._faketime += 0.01
                self.raised += 1
                raise ChaosError(f"injected chunk fault in {label}")
            out = real(chunk_size)
            self._faketime += 0.01 * self._slow.get(label, 1.0)
            return out

        eng.run_chunk = wrapped
        eng._chaos_wrapped = True

    # ------------------------------------------------------------- targeting

    def _live_labels(self) -> list[str]:
        return sorted(faults_lib.group_label(k)
                      for k in self.svc.scheduler.engines)

    def _running_jobs(self) -> list[str]:
        return sorted(
            j for eng in self.svc.scheduler.engines.values()
            for j in eng.job_ids
        )

    # ------------------------------------------------------------- injectors

    def fire(self, fault: Fault) -> bool:
        """Inject one fault; returns False when no valid target exists right
        now (e.g. a NaN fault with nothing running) — the schedule then
        simply skips it, deterministically."""
        kind = fault.kind
        if kind == "chunk_error":
            labels = self._live_labels()
            if not labels:
                return False
            label = self.rng.choice(labels)
            self._armed_errors[label] = (
                self._armed_errors.get(label, 0) + 1
            )
            return True
        if kind in ("nan_theta", "nan_data"):
            jobs = self._running_jobs()
            if not jobs:
                return False
            return self.poison(self.rng.choice(jobs),
                               what="theta" if kind == "nan_theta" else "data")
        if kind == "straggle":
            labels = self._live_labels()
            if not labels:
                return False
            self._slow[self.rng.choice(labels)] = 10.0
            return True
        if kind == "device_loss":
            self.svc.handle_device_loss(int(fault.arg or 0))
            return True
        raise ValueError(f"harness cannot fire {kind!r} inline")

    def poison(self, job_id: str, what: str = "theta") -> bool:
        """NaN one job's lane on device: its θ row (every chain), or one
        feature of its dataset copy. Direct surgery on the engine's live
        lane trees — exactly what a flaky HBM bank or a bad host transfer
        would do to that lane and nothing else."""
        eng = self.svc.scheduler.engine_of(job_id)
        if eng is None:
            return False
        self.poisoned.append(job_id)
        i = eng._lane_of(job_id)
        lanes = eng._lanes
        if what == "theta":
            st = lanes["states"]
            samp = st.sampler
            lanes["states"] = st._replace(
                sampler=samp._replace(
                    theta=samp.theta.at[i].set(jnp.nan)
                )
            )
        else:
            data = lanes["data"]
            lanes["data"] = data._replace(
                x=data.x.at[i, 0, 0].set(jnp.nan)
            )
        return True

    def recover_devices(self, n_devices: int):
        self.svc.handle_device_loss(n_devices)


def corrupt_checkpoint(directory, kind: str, rng: random.Random) -> int | None:
    """Damage the NEWEST on-disk checkpoint the way the schedule asked:
    flip one random bit of one random leaf file, truncate a leaf, or tear
    the manifest. Returns the damaged step (None when there is nothing to
    damage yet)."""
    ckpt = Checkpointer(directory, keep=0)
    step = ckpt.latest_step()
    if step is None:
        return None
    cdir = ckpt.dir / f"step_{step:08d}"
    if kind == "ckpt_torn":
        raw = (cdir / "manifest.json").read_bytes()
        (cdir / "manifest.json").write_bytes(raw[: max(1, len(raw) // 2)])
        return step
    leaves = sorted(cdir.glob("leaf_*.npy"))
    target = leaves[rng.randrange(len(leaves))]
    raw = bytearray(target.read_bytes())
    if kind == "ckpt_truncate":
        target.write_bytes(bytes(raw[: max(1, len(raw) // 2)]))
    else:  # ckpt_bitflip — any single bit, anywhere in the file
        pos = rng.randrange(len(raw))
        raw[pos] ^= 1 << rng.randrange(8)
        target.write_bytes(bytes(raw))
    return step


# --------------------------------------------------------------------------
# the verified chaos run
# --------------------------------------------------------------------------


def _chaos_jobs(*, n: int, d: int, max_samples: int, num_warmup: int):
    """A small heterogeneous tenant mix: three distinct batching groups
    (logistic K=1 ×2, logistic K=2, softmax K=1), so group-scoped faults
    have neighbors to spare and the straggler median is meaningful."""
    policy = TerminationPolicy(max_samples=max_samples)
    cap = max(16, n // 4)
    common = dict(capacity=cap, cand_capacity=cap, num_warmup=num_warmup,
                  policy=policy)
    jobs = []
    for i in range(2):
        jobs.append(Job(
            job_id=f"log1-{i}", family="logistic", seed=10 + i,
            data=logistic_data(jax.random.key(100 + i), n=n, d=d,
                               separation=1.5),
            **common,
        ))
    jobs.append(Job(
        job_id="log2-0", family="logistic", seed=20, num_chains=2,
        data=logistic_data(jax.random.key(200), n=n, d=d, separation=1.5),
        **common,
    ))
    jobs.append(Job(
        job_id="soft-0", family="softmax", seed=30, n_classes=3,
        data=softmax_data(jax.random.key(300), n=n, d=d, k=3),
        **common,
    ))
    return jobs


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        x, y = np.asarray(jax.device_get(x)), np.asarray(jax.device_get(y))
        if x.shape != y.shape or x.dtype != y.dtype:
            return False
        if not np.array_equal(x, y):  # bitwise: committed data is NaN-free
            return False
    return True


@dataclasses.dataclass
class ChaosReport:
    """One seed's verified outcome. ``fired``/``skipped`` partition the
    schedule; ``survivors`` matched the fault-free run bitwise,
    ``prefix_ok`` (quarantined/failed ids) matched as clean prefixes,
    ``lost`` retired inside a crashed step and were never delivered
    (a real at-most-once delivery gap — counted, not hidden).
    ``events`` aggregates every FaultEvent across restarts."""

    seed: int
    fired: list[Fault]
    skipped: list[Fault]
    survivors: list[str]
    prefix_ok: list[str]
    lost: list[str]
    restarts: int
    fallbacks: int
    events: list

    def summary(self) -> str:
        kinds = ",".join(f.kind for f in self.fired) or "-"
        return (f"seed={self.seed} fired=[{kinds}] "
                f"survivors={len(self.survivors)} "
                f"prefix_ok={len(self.prefix_ok)} lost={len(self.lost)} "
                f"restarts={self.restarts} fallbacks={self.fallbacks} "
                f"events={len(self.events)}")


def run_schedule(seed: int, *, n: int = 64, d: int = 3,
                 max_samples: int = 48, num_warmup: int = 8,
                 chunk_size: int = 16, checkpoint_every: int = 2,
                 directory=None, n_steps: int = 12, n_faults: int = 5,
                 kinds: tuple = ALL_KINDS, max_steps: int = 80,
                 slot_budget: int = 8) -> ChaosReport:
    """Run the tenant mix under ``schedule(seed)`` and verify the exactness
    contract under fire (module docstring). Raises AssertionError on any
    violation — a green return IS the chaos certificate for this seed."""
    jobs = _chaos_jobs(n=n, d=d, max_samples=max_samples,
                       num_warmup=num_warmup)

    # The fault-free reference: same jobs, same chunk size, no faults.
    # Stepped by hand so we learn the fault-free step count — the schedule
    # is clamped to it, else short runs would drain before any fault fires.
    ref_svc = Service(slot_budget=slot_budget, chunk_size=chunk_size)
    for j in jobs:
        ref_svc.submit(j)
    ref_steps = 0
    while ref_svc.active():
        ref_svc.step()
        ref_steps += 1
        assert ref_steps <= max_steps, "fault-free reference did not drain"
    ref = dict(ref_svc._results)

    if directory is None:
        directory = tempfile.mkdtemp(prefix="chaos_ckpt_")
    rng = random.Random(seed)
    plan = schedule(seed, n_steps=min(n_steps, ref_steps + 1),
                    n_faults=n_faults, kinds=kinds)
    by_step: dict[int, list[Fault]] = {}
    for f in plan:
        by_step.setdefault(f.step, []).append(f)

    def fresh_service(restore: bool) -> Service:
        ckpt = Checkpointer(directory, keep=0)  # keep all: fallback depth
        kw = dict(chunk_size=chunk_size, checkpoint_every=checkpoint_every,
                  retry=RetryPolicy(max_retries=2, backoff_s=0.0),
                  straggler_threshold=4.0)
        if restore:
            svc = Service.restore(ckpt, **kw)
        else:
            svc = Service(slot_budget=slot_budget, checkpointer=ckpt, **kw)
        return svc

    svc = fresh_service(restore=False)
    harness = ChaosHarness(svc, rng)
    for j in jobs:
        svc.submit(j)

    seen: dict[str, JobResult] = {}
    events: list = []
    fired: list[Fault] = []
    skipped: list[Fault] = []
    pending_recovery: dict[int, int] = {}  # step -> device count to restore
    pending_poison: set = set()  # NaN'd jobs awaiting sentinel adjudication
    restarts = 0
    replays_checked = 0
    chunk_raised = 0  # injected chunk errors that actually raised, all lives

    def collect():
        """Deliver retired results to the 'client'. A job that retires a
        second time (crash rewound it past its first retirement) must
        reproduce its first result bitwise — exact replay, verified."""
        nonlocal replays_checked
        for job_id, res in svc._results.items():
            if job_id in seen:
                if res is not seen[job_id]:
                    assert res.reason == seen[job_id].reason and _tree_equal(
                        res.results, seen[job_id].results
                    ), f"replayed job {job_id} diverged from first delivery"
                    replays_checked += 1
            seen[job_id] = res

    def cold_restart() -> bool:
        """Simulated process death: drop ALL in-memory state, come back
        from disk. Returns False when no checkpoint survives (the service
        cannot restart; callers assert the refusal was loud)."""
        nonlocal svc, harness, restarts, chunk_raised
        restarts += 1
        chunk_raised += harness.raised
        pending_poison.clear()  # in-memory poison dies with the process
        events.extend(svc.faults)
        ckpt_probe = Checkpointer(directory, keep=0)  # runs sweep recovery
        if ckpt_probe.latest_intact_step() is None:
            return False
        svc = fresh_service(restore=True)
        harness = ChaosHarness(svc, rng)
        return True

    step_i = 0
    while svc.active():
        assert step_i < max_steps, (
            f"chaos run (seed {seed}) did not drain in {max_steps} steps"
        )
        if step_i in pending_recovery:
            harness.recover_devices(pending_recovery.pop(step_i))
        for fault in by_step.get(step_i, ()):
            if fault.kind == "device_loss":
                harness.fire(fault)
                fired.append(fault)
                pending_recovery[step_i + 2] = max(1, len(jax.devices()))
            elif fault.kind.startswith("kill_"):
                point = fault.kind[len("kill_"):]
                ck = svc.checkpointer
                ck._kill_hook = lambda p, point=point: (
                    (_ for _ in ()).throw(InjectedKill(p))
                    if p == point else None
                )
                try:
                    svc.checkpoint(blocking=True)
                except InjectedKill:
                    fired.append(fault)
                    if not cold_restart():
                        skipped.append(fault)  # nothing on disk yet
                        break
                else:
                    # kill point never reached (e.g. "parked" without a
                    # same-step re-save) — save completed; that's fine.
                    ck._kill_hook = None
                    fired.append(fault)
            elif fault.kind in _CKPT_KINDS:
                svc.checkpointer.wait()
                if len(svc.checkpointer.all_steps()) < 2:
                    skipped.append(fault)  # nothing intact to fall back to
                    continue
                damaged = corrupt_checkpoint(directory, fault.kind, rng)
                fired.append(fault)
                collect()  # the client had these; a crash can't unsend them
                ok = cold_restart()
                assert ok, "fallback restart failed with an intact step on disk"
                assert svc.restored_from_step != damaged, (
                    f"restore silently loaded corrupt step {damaged}"
                )
                assert any(e.kind == "checkpoint_fallback"
                           for e in svc.faults), (
                    "corrupt-step fallback emitted no checkpoint_fallback event"
                )
            else:
                (fired if harness.fire(fault) else skipped).append(fault)
                pending_poison.update(harness.poisoned)
                harness.poisoned.clear()
        try:
            svc.step()
        except InjectedKill:
            # A periodic checkpoint tripped a still-armed kill hook.
            if not cold_restart():
                raise AssertionError("no intact checkpoint after kill") from None
        collect()
        # Adjudicate every pending poison now: its group ran a chunk this
        # step, so the sentinel either quarantined it, or the job left the
        # fleet first (group failure / suspension), or the sentinel MISSED —
        # which is exactly the bug this harness exists to catch.
        for job_id in list(pending_poison):
            if any(e.kind == "nonfinite" and e.job_id == job_id
                   for e in svc.faults):
                pending_poison.discard(job_id)
            elif svc.scheduler.engine_of(job_id) is None:
                pending_poison.discard(job_id)  # retired/suspended first
            else:
                raise AssertionError(
                    f"sentinel missed NaN poison on running job {job_id}"
                )
        step_i += 1
    events.extend(svc.faults)

    # ---------------------------------------------------------- verification
    survivors, prefix_ok, lost = [], [], []
    for job in jobs:
        job_id = job.job_id
        res = seen.get(job_id)
        ref_res = ref[job_id]
        if res is None:
            lost.append(job_id)  # retired inside a crashed step, undelivered
            continue
        if res.reason in ("max_samples", "converged"):
            assert res.committed == ref_res.committed, (
                f"survivor {job_id}: committed {res.committed} != "
                f"fault-free {ref_res.committed}"
            )
            assert _tree_equal(res.results, ref_res.results), (
                f"survivor {job_id}: results differ from the fault-free run"
            )
            survivors.append(job_id)
        elif res.reason in ("quarantined", "failed"):
            assert res.committed <= ref_res.committed
            got = np.asarray(jax.device_get(res.samples()))
            want = np.asarray(jax.device_get(
                ref_res.results["trace"]["theta"]
            ))[:, : res.committed]
            assert np.array_equal(got, want), (
                f"faulted job {job_id}: committed prefix is not bitwise the "
                f"fault-free prefix"
            )
            assert np.isfinite(got).all(), (
                f"faulted job {job_id}: NaN leaked into committed samples"
            )
            prefix_ok.append(job_id)
        else:
            raise AssertionError(
                f"job {job_id} retired with unexpected reason {res.reason!r}"
            )

    fallbacks = sum(1 for e in events if e.kind == "checkpoint_fallback")
    fired_ckpt = [f for f in fired if f.kind in _CKPT_KINDS]
    if fired_ckpt:
        assert fallbacks >= 1, (
            "checkpoint corruption fired but no fallback event was recorded"
        )
    chunk_raised += harness.raised
    if chunk_raised:
        assert any(e.kind == "chunk_error" for e in events), (
            "an injected chunk error raised but no chunk_error event "
            "was recorded"
        )
    return ChaosReport(
        seed=seed, fired=fired, skipped=skipped, survivors=survivors,
        prefix_ok=prefix_ok, lost=lost, restarts=restarts,
        fallbacks=fallbacks, events=events,
    )


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2, 3])
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--max-samples", type=int, default=48)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--n-faults", type=int, default=5)
    ap.add_argument("--checkpoint-every", type=int, default=2,
                    help="service checkpoint cadence; 1 gives short runs "
                         "enough on-disk steps for the ckpt_* faults to fire")
    ap.add_argument("--kinds", nargs="+", default=None, metavar="KIND",
                    help="restrict the schedule to these fault kinds "
                         f"(default: all of {', '.join(ALL_KINDS)})")
    args = ap.parse_args(argv)
    kinds = tuple(args.kinds) if args.kinds else ALL_KINDS
    unknown = set(kinds) - set(ALL_KINDS)
    if unknown:
        ap.error(f"unknown fault kinds: {sorted(unknown)}")
    for seed in args.seeds:
        report = run_schedule(
            seed, n=args.n, max_samples=args.max_samples,
            chunk_size=args.chunk_size, n_faults=args.n_faults,
            checkpoint_every=args.checkpoint_every, kinds=kinds,
        )
        print("OK", report.summary())
    print(f"chaos suite green: {len(args.seeds)} seeds")


if __name__ == "__main__":
    main()
