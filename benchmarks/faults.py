"""Cost of the fault-tolerance machinery (PR 10 hardening).

Three prices are worth knowing, none worth guessing:

  * **Durability** — ``Checkpointer.save`` now fsyncs every leaf file, the
    manifest, the tmp dir and the parent, and records per-file CRC-32s.
    Timed per save on a serve-sized lane tree, alongside ``verify`` (the
    full integrity re-read) and a verified ``restore``.
  * **Sentinel** — every ``GroupEngine.run_chunk`` reduces an all-finite
    flag across the lane trees inside the jitted chunk. Measured as the
    wall-clock delta between two identical service drains (the sentinel is
    always on, so this is service wall time vs the solo-path equivalent —
    reported as supervised-vs-plain service wall ratio with retry/straggler
    machinery active vs default).
  * **Recovery** — one full chaos schedule (seeded faults, cold restarts,
    verified restores) vs the fault-free drain of the same workload: the
    end-to-end overhead of surviving.

Writes ``BENCH_flymc.json`` under ``"faults"``.

    PYTHONPATH=src python -m benchmarks.faults [--quick]
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from benchmarks._util import job_mix, merge_write

from repro.checkpoint import Checkpointer
from repro.serve import RetryPolicy, Service
from repro.testing import chaos


def _drain(jobs, *, chunk_size, budget, supervised: bool,
           checkpointer=None, checkpoint_every=None):
    kw = {}
    if supervised:
        kw = dict(retry=RetryPolicy(max_retries=2, backoff_s=0.0),
                  straggler_threshold=4.0)
    svc = Service(slot_budget=budget, chunk_size=chunk_size,
                  checkpointer=checkpointer,
                  checkpoint_every=checkpoint_every, **kw)
    t0 = time.perf_counter()
    for j in jobs:
        svc.submit(j)
    svc.run()
    return time.perf_counter() - t0, svc


def _time_checkpoint_cycle(svc: Service, reps: int):
    """Per-op seconds for (durable save, verify, verified restore) on the
    live service's lane tree."""
    ck = svc.checkpointer
    saves, verifies, restores = [], [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        svc.checkpoint(blocking=True)
        saves.append(time.perf_counter() - t0)
        step = ck.latest_step()
        t0 = time.perf_counter()
        problems = ck.verify(step)
        verifies.append(time.perf_counter() - t0)
        assert problems == []
        t0 = time.perf_counter()
        Service.restore(ck, verify=True)
        restores.append(time.perf_counter() - t0)
    return (float(np.median(saves)), float(np.median(verifies)),
            float(np.median(restores)))


def main(quick: bool = False, seed: int = 0) -> dict:
    if quick:
        kw = dict(n=512, d=8, max_samples=64, num_warmup=16)
        chunk_size, budget, reps = 16, 16, 3
        chaos_kw = dict(n=256, max_samples=48, chunk_size=8,
                        checkpoint_every=1)
    else:
        kw = dict(n=2048, d=16, max_samples=256, num_warmup=64)
        chunk_size, budget, reps = 32, 16, 5
        chaos_kw = dict(n=1024, max_samples=128, chunk_size=16,
                        checkpoint_every=1)
    jobs = job_mix(seed, 8, auto_terminate=False, **kw)

    # Warmup compile on identical shapes, then time both drains.
    _drain(job_mix(seed, 8, auto_terminate=False, **kw),
           chunk_size=chunk_size, budget=budget, supervised=False)
    plain_s, _ = _drain(job_mix(seed, 8, auto_terminate=False, **kw),
                        chunk_size=chunk_size, budget=budget,
                        supervised=False)
    sup_s, _ = _drain(jobs, chunk_size=chunk_size, budget=budget,
                      supervised=True)

    # Checkpoint cycle timings on a mid-flight service (live lane trees).
    with tempfile.TemporaryDirectory(prefix="bench_faults_") as d:
        svc = Service(slot_budget=budget, chunk_size=chunk_size,
                      checkpointer=Checkpointer(d))
        for j in job_mix(seed, 8, auto_terminate=False, **kw):
            svc.submit(j)
        svc.step()
        svc.step()
        n_bytes = sum(
            np.asarray(jax.device_get(l)).nbytes
            for eng in svc.scheduler.engines.values()
            for jid in eng.job_ids
            for l in jax.tree.leaves(eng.lane_of(jid))
        )
        save_s, verify_s, restore_s = _time_checkpoint_cycle(svc, reps)

    # End-to-end chaos schedule vs its own fault-free reference.
    with tempfile.TemporaryDirectory(prefix="bench_chaos_") as d:
        t0 = time.perf_counter()
        report = chaos.run_schedule(seed, directory=d, n_faults=4,
                                    **chaos_kw)
        chaos_s = time.perf_counter() - t0

    record = {
        "quick": quick,
        "supervision": {
            "plain_wall_s": round(plain_s, 3),
            "supervised_wall_s": round(sup_s, 3),
            "overhead_frac": round(sup_s / plain_s - 1, 4),
        },
        "checkpoint": {
            "tree_mbytes": round(n_bytes / 1e6, 3),
            "durable_save_s": round(save_s, 4),
            "verify_s": round(verify_s, 4),
            "verified_restore_s": round(restore_s, 4),
        },
        "chaos": {
            "schedule_wall_s": round(chaos_s, 3),
            "fired": [f.kind for f in report.fired],
            "restarts": report.restarts,
            "fallbacks": report.fallbacks,
            "survivors": len(report.survivors),
            "clean_prefixes": len(report.prefix_ok),
        },
    }
    merge_write({"faults": record})
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    rec = main(quick=args.quick)
    sup = rec["supervision"]
    ck = rec["checkpoint"]
    print(f"supervision overhead: {sup['overhead_frac'] * 100:.2f}% "
          f"({sup['plain_wall_s']}s -> {sup['supervised_wall_s']}s)")
    print(f"checkpoint ({ck['tree_mbytes']} MB): save {ck['durable_save_s']}s"
          f" verify {ck['verify_s']}s restore {ck['verified_restore_s']}s")
    print(f"chaos: {rec['chaos']}")
