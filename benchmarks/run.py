"""Benchmark harness entry point (brief deliverable d).

One benchmark per paper table/figure plus the roofline headline:
  * Table 1 (three experiments × three algorithms) — benchmarks/table1.py
  * Fig 1 / §3.1 bound-tightness claim       — benchmarks/bound_tightness.py
  * §Roofline headline cells (from the dry-run JSONs, if present)

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--full]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.bound_tightness import check_paper_claim
from benchmarks.table1 import format_results, table1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="5%% scale, 400 iters (CI-sized)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale OPV (N=1.8M)")
    args = ap.parse_args()

    rows: list[str] = []

    # --- Table 1 -----------------------------------------------------------
    if args.quick:
        res = table1(scale=0.05, iters=400, burn=100, opv_n=20_000)
    else:
        res = table1(
            scale=1.0, iters=1200, burn=300,
            opv_n=1_800_000 if args.full else 100_000,
        )
    print(format_results(res))
    for r in res:
        rows.append(
            f"table1/{r.name},{r.us_per_iter:.1f},"
            f"q={r.queries_per_iter:.0f};ess1000={r.ess_per_1000:.2f};"
            f"speedup={r.speedup:.2f}"
        )

    # --- driver overhead (writes BENCH_flymc.json) -------------------------
    from benchmarks.driver_overhead import main as bench_driver

    rec = bench_driver(quick=args.quick)
    ov_ratio = rec["host_overhead_ratio"]
    rows.append(
        f"driver/scan,{rec['scan_driver']['us_per_step']:.1f},"
        f"legacy_us={rec['legacy_host_loop']['us_per_step']:.1f};"
        f"us_ratio={rec['us_per_step_ratio']:.2f};"
        f"overhead_ratio="
        f"{'n/a' if ov_ratio is None else f'{ov_ratio:.2f}'}"
    )

    # --- θ-update backend: jnp vs fused pallas kernel ----------------------
    from benchmarks.bright_glm import main as bench_backend

    brec = bench_backend(quick=args.quick)
    rows.append(
        f"bright_glm/pallas,{brec['pallas']['us_per_eval']:.1f},"
        f"jnp_us={brec['jnp']['us_per_eval']:.1f};"
        f"interpret={brec['pallas']['interpret']}"
    )

    # --- z-update engine: jnp vs fused streaming kernel --------------------
    from benchmarks.z_update import main as bench_z

    zrec = bench_z(quick=args.quick)
    rows.append(
        f"z_update/fused,{zrec['fused']['us_per_z_phase']:.1f},"
        f"jnp_us={zrec['jnp']['us_per_z_phase']:.1f};"
        f"bytes_ratio={zrec['bytes_model_ratio']:.1f};"
        f"interpret={zrec['fused']['interpret']}"
    )

    # --- chain scaling: vmap vs chain-batched megakernels ------------------
    from benchmarks.chain_scaling import main as bench_chains

    srec = bench_chains(quick=args.quick)
    top = str(max(int(k) for k in srec["batched"]))
    rows.append(
        f"chain_scaling/batched{top},"
        f"{srec['batched'][top]['us_per_step']:.1f},"
        f"vmap_us={srec['vmap'][top]['us_per_step']:.1f};"
        f"marginal_us={srec['batched'][top]['marginal_us_per_chain']:.1f};"
        f"sublinear={srec['batched'][top]['sublinear']};"
        f"interpret={srec['interpret']}"
    )

    # --- streaming collectors vs dense FullTrace ---------------------------
    from benchmarks.collectors import main as bench_collectors

    crec = bench_collectors(quick=args.quick)["collectors"]
    rows.append(
        f"collectors/streaming,{crec['streaming']['us_per_step']:.1f},"
        f"full_us={crec['full_trace']['us_per_step']:.1f};"
        f"overhead_us={crec['overhead_us_per_step']:.2f};"
        f"bytes_ratio={crec['bytes_ratio']:.0f}"
    )

    # --- serving: continuous batching vs sequential ------------------------
    from benchmarks.serving import main as bench_serving

    vrec = bench_serving(quick=args.quick)
    rows.append(
        f"serving/batched,{vrec['service']['wall_s'] * 1e6 / vrec['n_jobs']:.0f},"
        f"seq_s={vrec['sequential']['wall_s']};speedup={vrec['speedup']};"
        f"p95_s={vrec['service']['latency_p95_s']};"
        f"occupancy={vrec['service']['occupancy_mean']};"
        f"steps_saved={vrec['auto_termination']['steps_saved_frac']};"
        f"bitwise={vrec['fixed_length_results_bitwise_equal']}"
    )

    # --- fault tolerance: durable checkpoints, supervision, chaos ----------
    from benchmarks.faults import main as bench_faults

    frec = bench_faults(quick=args.quick)
    rows.append(
        f"faults/save,{frec['checkpoint']['durable_save_s'] * 1e6:.0f},"
        f"verify_s={frec['checkpoint']['verify_s']};"
        f"restore_s={frec['checkpoint']['verified_restore_s']};"
        f"supervision_overhead={frec['supervision']['overhead_frac']};"
        f"chaos_restarts={frec['chaos']['restarts']};"
        f"chaos_survivors={frec['chaos']['survivors']}"
    )

    # --- static analysis: cost fingerprints of every hot-path jit ----------
    from benchmarks.static_analysis import main as bench_static

    arec = bench_static(quick=args.quick)
    worst_rng = max(
        e["max_rng_size"] for name, e in arec["entry_points"].items()
        if name != "step.jnp"  # the registered known-bad engine
    )
    rows.append(
        f"static_analysis/sweep,0.0,"
        f"ok={arec['ok']};entry_points={len(arec['entry_points'])};"
        f"worst_fused_rng={worst_rng}"
    )

    # --- §3.1 bound tightness ---------------------------------------------
    bt = check_paper_claim()
    print(
        f"\nbound tightness (xi=1.5): max p(bright)="
        f"{bt['claim_max_p_bright']:.5f} in 0.1<L<0.9 "
        f"(paper: <0.02 — {'holds' if bt['claim_holds'] else 'FAILS'})"
    )
    rows.append(
        f"bound_tightness/xi1.5,0.0,"
        f"max_p={bt['claim_max_p_bright']:.5f};holds={bt['claim_holds']}"
    )

    # --- roofline headline (if the dry-run has been run) --------------------
    results = Path(__file__).parent / "results"
    headline = [
        ("qwen1.5-110b", "train_4k"),
        ("rwkv6-7b", "train_4k"),
        ("mixtral-8x7b", "decode_32k"),
    ]
    for arch, shape in headline:
        f = results / f"dryrun_single_{arch.replace('.', '_')}_{shape}.json"
        if not f.exists():
            continue
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        rows.append(
            f"roofline/{arch}/{shape},{r['compute_s']*1e6:.0f},"
            f"mem_s={r['memory_s']:.3f};coll_s={r['collective_s']:.3f};"
            f"dominant={r['dominant']};fits={rec['memory']['fits_16g']}"
        )

    print("\nname,us_per_call,derived")
    for row in rows:
        print(row)


if __name__ == "__main__":
    main()
