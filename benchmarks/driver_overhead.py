"""Per-step host-overhead benchmark: legacy host loop vs scan driver.

Measures the quickstart problem (MAP-tuned FlyMC logistic regression) three
ways:

  * ``legacy_host_loop`` — the pre-api driver: one jitted step per Python
    iteration with ~4 ``device_get`` syncs for trace scalars (reconstructed
    here verbatim, since ``run_chain`` now delegates to the driver);
  * ``scan_driver`` — ``repro.api.sample``: chunked ``lax.scan``, one sync
    per chunk;
  * both report µs/step, likelihood queries/iter, and ESS per query.

Emits ``BENCH_flymc.json`` at the repo root (schema below) so successive
PRs can track the per-step overhead trajectory.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks._util import BENCH_PATH, best_of, merge_write
from repro import api
from repro.core import diagnostics
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel


def _tuned_model(n=5000, d=21, seed=0):
    data = logistic_data(jax.random.key(seed), n=n, d=d, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)
    theta_map = model.map_estimate(jax.random.key(1), steps=300)
    return model.map_tuned(theta_map), theta_map


def _ess_per_query(thetas, burn, total_q):
    s = np.asarray(thetas)[burn:]
    ess = diagnostics.effective_sample_size(s[:, : min(10, s.shape[1])])
    return float(ess / max(total_q, 1))


def _legacy_host_loop(alg, state, key, iters):
    """The seed's run_chain driver, verbatim: per-step dispatch + 4 syncs."""
    step = jax.jit(alg.step)
    samples, trace = [], []
    total_q = 0
    for i in range(iters):
        state, st = step(jax.random.fold_in(key, i), state)
        total_q += int(jax.device_get(st.lik_queries))
        samples.append(jax.device_get(state.sampler.theta))
        trace.append(
            {
                "n_bright": int(jax.device_get(st.n_bright)),
                "accept_prob": float(jax.device_get(st.accept_prob)),
                "joint_lp": float(jax.device_get(st.joint_lp)),
            }
        )
    return samples, total_q


def bench(n=5000, d=21, iters=800, burn=200, chunk_size=100, q_db=0.01):
    tuned, _ = _tuned_model(n=n, d=d)
    # Capacity sized so the bright set never overflows mid-run: both drivers
    # then execute the identical chain and the timing deltas are pure driver
    # overhead, not capacity-growth recompiles.
    alg = api.firefly(
        tuned, kernel="rwmh", capacity=1024, cand_capacity=1024, q_db=q_db,
        step_size=0.03, adapt_target="auto",
    )
    key = jax.random.key(3)

    def us_best_of(fn):
        # best-of-3: shared-machine timer noise exceeds the scan's
        # per-chunk overhead, so a single rep can't resolve it.
        wall, out = best_of(fn)
        return wall * 1e6 / iters, out

    # --- legacy host loop --------------------------------------------------
    k_init, k_steps = jax.random.split(key)
    state0 = jax.jit(alg.init)(k_init, alg.default_position)
    _legacy_host_loop(alg, state0, k_steps, 3)  # warm up the jit cache
    us_legacy, (samples, total_q_legacy) = us_best_of(
        lambda: _legacy_host_loop(alg, state0, k_steps, iters)
    )

    # --- device floor: whole run as one warm scan (≈ pure device compute) --
    api.sample(alg, key, iters, chunk_size=iters)  # warm-up / compile
    us_floor, _ = us_best_of(
        lambda: api.sample(alg, key, iters, chunk_size=iters).theta
    )

    # --- scan driver at the default chunking (same key → same chain) -------
    api.sample(alg, key, 2 * chunk_size, chunk_size=chunk_size)  # warm-up
    us_scan, trace = us_best_of(
        lambda: api.sample(alg, key, iters, chunk_size=chunk_size)
    )
    # Host overhead = µs/step beyond the on-device floor. The scan driver
    # can time within noise of (or below) the floor; clamp only the
    # *reported* per-driver overheads, never the ratio's denominator —
    # dividing by a clamped 1.0 µs turned the ratio into a copy of the
    # legacy overhead in absolute µs.
    ov_legacy_raw = us_legacy - us_floor
    ov_scan_raw = us_scan - us_floor
    ov_legacy = max(ov_legacy_raw, 0.0)
    ov_scan = max(ov_scan_raw, 0.0)
    # The overhead ratio is only meaningful when the scan overhead is
    # resolvable above timer noise; otherwise record null and let the
    # whole-step ratio carry the comparison.
    resolvable = ov_scan_raw > 0.02 * us_floor
    ov_ratio = (ov_legacy_raw / ov_scan_raw) if resolvable else None
    total_q_scan = int(trace.total_queries)
    record = {
        "problem": {"name": "quickstart-logistic", "n": n, "d": d,
                    "kernel": "rwmh", "iters": iters, "q_db": q_db},
        "device_floor_us_per_step": us_floor,
        "legacy_host_loop": {
            "us_per_step": us_legacy,
            "host_overhead_us_per_step": ov_legacy,
            "lik_queries_per_iter": total_q_legacy / iters,
            "ess_per_query": _ess_per_query(
                np.stack(samples), burn, total_q_legacy
            ),
        },
        "scan_driver": {
            "us_per_step": us_scan,
            "host_overhead_us_per_step": ov_scan,
            "chunk_size": chunk_size,
            "lik_queries_per_iter": total_q_scan / iters,
            "ess_per_query": _ess_per_query(
                trace.theta[0], burn, total_q_scan
            ),
        },
        "us_per_step_ratio": us_legacy / us_scan,
        "host_overhead_ratio": ov_ratio,
    }
    return record


def main(quick=False):
    record = bench(iters=300 if quick else 800, burn=100 if quick else 200)
    # Merge-write: other benchmarks (benchmarks/bright_glm.py) own sibling
    # top-level keys in the same JSON.
    merge_write(record)
    leg, scan = record["legacy_host_loop"], record["scan_driver"]
    print(f"device floor:     {record['device_floor_us_per_step']:8.1f} us/step")
    print(f"legacy host loop: {leg['us_per_step']:8.1f} us/step  "
          f"(overhead {leg['host_overhead_us_per_step']:.1f})  "
          f"q/iter={leg['lik_queries_per_iter']:.0f}  "
          f"ess/query={leg['ess_per_query']:.2e}")
    print(f"scan driver:      {scan['us_per_step']:8.1f} us/step  "
          f"(overhead {scan['host_overhead_us_per_step']:.1f})  "
          f"q/iter={scan['lik_queries_per_iter']:.0f}  "
          f"ess/query={scan['ess_per_query']:.2e}")
    ratio = record["host_overhead_ratio"]
    print(f"us/step ratio (legacy/scan): {record['us_per_step_ratio']:.2f}x; "
          f"host-overhead ratio: "
          f"{'unresolved (scan within timer noise of floor)' if ratio is None else f'{ratio:.1f}x'} "
          f"(wrote {BENCH_PATH.name})")
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
