"""z-update engine benchmark: jnp (length-N) vs fused (streamed) z-phase.

Times ONE z-phase — bright→dark decisions, dark→bright candidate selection
+ δ + decisions, partition maintenance — for the two engines on the
quickstart problem, plus the full chain through ``repro.api.sample``:

  * ``z_backend="jnp"``   — three (N,) ``jax.random.uniform`` draws, (N,)
    boolean scatters, and a full-N cumsum re-partition (``from_z``) every
    step;
  * ``z_backend="fused"`` — the ``kernels/z_update`` streaming candidate
    kernel (in-kernel counter RNG, in-kernel compaction) + O(C) counter
    uniforms on the bright/candidate buffers + O(changed) incremental
    partition swaps (``brightness.apply_flips``).

Reports µs per z-phase, µs per full step, and an analytic HBM-traffic model
(bytes per z-phase) for each engine. Off-TPU the fused numbers run the
kernel in interpret mode — correctness-path timings, not kernel speed — and
are flagged (``interpret: true``), same policy as ``benchmarks/bright_glm``.
Results merge into ``BENCH_flymc.json`` under ``z_update_backend``.
"""

from __future__ import annotations

import jax

import jax.numpy as jnp

from benchmarks._util import BENCH_PATH, best_of, merge_write, quickstart_problem
from repro import api
from repro.analysis.kernels import derive_traffic
from repro.core import brightness, flymc
from repro.kernels.common import default_interpret
from repro.kernels.z_update.ops import z_candidates


def _bytes_model(n: int, capacity: int, q_db: float) -> dict:
    """Analytic HBM traffic per z-phase (4-byte lanes), by term.

    jnp: hand model — every term is length-N: three uniform arrays (write +
    read), two (N,) boolean scatter round-trips for z, and the from_z
    rebuild (read z, two cumsums r+w, write tab, scatter arr); an XLA
    pipeline with no BlockSpecs to derive from. fused: the in-kernel terms
    (``kernel_*`` — the padded partition-array stream, the candidate
    writeback, the count scalar) are derived from the kernel's own
    BlockSpecs and grid by ``repro.analysis.kernels.derive_traffic``, the
    same model the ``kernel-bytes`` sweep rule pins; only the XLA glue
    around the kernel stays hand-modeled: the pad/reshape round-trip
    feeding it, the O(C) counter-uniform/bright buffers outside the
    derived candidate writeback, and the O(changed) ``apply_flips``
    scatters.
    """
    c = capacity
    jnp_terms = {
        "uniform_draws_3xN": 3 * 2 * 4 * n,
        "z_scatters_2xN": 2 * 2 * 4 * n,
        "from_z_rebuild": 8 * 4 * n,  # z + 2 cumsums (r+w) + tab + arr
        "candidate_buffers_O(C)": 6 * 4 * c,
    }
    s, i32 = jax.ShapeDtypeStruct, jnp.int32
    (model,) = derive_traffic(
        lambda arr, num, kw: z_candidates(
            arr, num, kw, q_db=q_db, cand_capacity=c, interpret=True
        ),
        s((n,), i32), s((), i32), s((2,), i32),
    ).values()
    fused_terms = {
        **{f"kernel_{name}": op["bytes"]
           for name, op in model["per_operand"].items()},
        "arr_pad_reshape": 2 * 4 * n,
        "bright_buffers_O(C)": 9 * 4 * c,
        "apply_flips_O(changed)": 8 * 4 * c,
    }
    return {
        "jnp": {"terms": jnp_terms, "total": sum(jnp_terms.values())},
        "fused": {"terms": fused_terms, "total": sum(fused_terms.values())},
    }


def _z_phase_fn(alg, data):
    """jit'd (key, state) -> updated bright state, isolating the z-phase."""
    spec = alg.spec

    def z_phase(key, state):
        theta = state.sampler.theta
        if spec.z_backend == "fused":
            bright, delta_full, q, ov = flymc._fused_z_update(
                spec, data, key, theta, state.bright, state.delta_full,
                state.sampler.aux,
            )
        else:
            z, delta_full, q, ov = flymc._implicit_z_update(
                spec, data, key, theta, state.bright, state.delta_full,
                state.sampler.aux,
            )
            bright = brightness.from_z(z)
        return bright.num, delta_full.sum(), q, ov

    return jax.jit(z_phase)


def bench(n=5000, d=21, capacity=1024, iters=300, q_db=0.01, reps=3):
    tuned = quickstart_problem(n, d)
    key = jax.random.key(3)
    interpret = default_interpret()

    record = {"problem": {"name": "quickstart-logistic", "n": n, "d": d,
                          "capacity": capacity, "iters": iters, "q_db": q_db}}
    bmodel = _bytes_model(n, capacity, q_db)

    for zb in ("jnp", "fused"):
        alg = api.firefly(
            tuned, kernel="rwmh", capacity=capacity, cand_capacity=capacity,
            q_db=q_db, step_size=0.03, adapt_target="auto", z_backend=zb,
        )
        state = jax.jit(alg.init)(jax.random.key(1), alg.default_position)
        z_phase = _z_phase_fn(alg, tuned.data)
        n_evals = 50
        keys = [jax.random.fold_in(key, i) for i in range(n_evals)]
        z_phase(keys[0], state)  # compile
        wall_z, _ = best_of(
            lambda: [z_phase(k, state) for k in keys][-1], reps=reps
        )
        us_z = wall_z * 1e6 / n_evals

        api.sample(alg, key, 2, chunk_size=2)  # compile chunk
        wall_step, _ = best_of(
            lambda: api.sample(alg, key, iters, chunk_size=iters), reps=reps
        )
        us_step = wall_step * 1e6 / iters

        record[zb] = {
            "us_per_z_phase": us_z,
            "us_per_step": us_step,
            "hbm_bytes_per_z_phase_model": bmodel[zb]["total"],
            "hbm_bytes_terms": bmodel[zb]["terms"],
            "interpret": interpret if zb == "fused" else False,
        }
    record["bytes_model_ratio"] = (
        bmodel["jnp"]["total"] / bmodel["fused"]["total"]
    )
    # Interpret-mode wall times are not kernel speed — null the ratio there,
    # same policy as bright_glm_backend / driver_overhead.
    record["us_per_z_phase_ratio"] = (
        None if interpret
        else record["jnp"]["us_per_z_phase"] / record["fused"]["us_per_z_phase"]
    )
    return record


def main(quick=False):
    record = bench(
        n=2000 if quick else 5000,
        capacity=512 if quick else 1024,
        iters=100 if quick else 300,
    )
    merge_write({"z_update_backend": record})
    for zb in ("jnp", "fused"):
        r = record[zb]
        tag = " (interpret)" if r["interpret"] else ""
        print(f"{zb:>6}{tag}: {r['us_per_z_phase']:9.1f} us/z-phase  "
              f"{r['us_per_step']:9.1f} us/step  "
              f"~{r['hbm_bytes_per_z_phase_model']/1e3:.1f} KB HBM/z-phase")
    ratio = record["us_per_z_phase_ratio"]
    print(f"z-phase bytes-model ratio (jnp/fused): "
          f"{record['bytes_model_ratio']:.1f}x; wall ratio: "
          f"{'n/a (interpret mode — not kernel speed)' if ratio is None else f'{ratio:.2f}x'} "
          f"(wrote {BENCH_PATH.name})")
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
