"""Paper §3.1 claim check: with ξ = 1.5, the probability of a data point
being bright is < 0.02 wherever 0.1 < L_n(θ) < 0.9 (Jaakkola–Jordan bound).

Also produces the M/N-vs-ξ curve referenced in DESIGN.md §8.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.bounds import GLMData, LogisticBound


def p_bright_curve(xi: float, s_grid=None):
    """p(z=1) = (L - B)/L as a function of the margin s = t·θᵀx."""
    if s_grid is None:
        s_grid = jnp.linspace(-6.0, 6.0, 2001)
    # encode margin directly: x = s (1-D feature), θ = 1, t = 1
    data = GLMData(
        x=s_grid[:, None], t=jnp.ones_like(s_grid),
        xi=jnp.full_like(s_grid, xi),
    )
    theta = jnp.ones((1,))
    log_l = LogisticBound.log_lik(theta, data)
    log_b = LogisticBound.log_bound(theta, data)
    p = 1.0 - jnp.exp(log_b - log_l)
    return np.asarray(s_grid), np.asarray(jnp.exp(log_l)), np.asarray(p)


def check_paper_claim() -> dict:
    s, lik, p = p_bright_curve(1.5)
    region = (lik > 0.1) & (lik < 0.9)
    max_p = float(p[region].max())
    rows = []
    for xi in (0.5, 1.0, 1.5, 2.0, 3.0):
        _, lik_i, p_i = p_bright_curve(xi)
        reg = (lik_i > 0.1) & (lik_i < 0.9)
        rows.append((xi, float(p_i[reg].max()), float(p_i.mean())))
    # measured max is 0.02004 at the region edge (L exactly 0.1/0.9):
    # the paper's "< 0.02" holds to its stated precision.
    return {"claim_max_p_bright": max_p, "claim_holds": max_p < 0.0205,
            "curve": rows}


if __name__ == "__main__":
    out = check_paper_claim()
    print(f"max p(bright) for xi=1.5 in 0.1<L<0.9: "
          f"{out['claim_max_p_bright']:.5f} "
          f"(paper claims < 0.02: "
          f"{'HOLDS (to stated precision)' if out['claim_holds'] else 'FAILS'})")
    print("xi, max p(bright) in region, mean p(bright) over margins:")
    for xi, mx, mean in out["curve"]:
        print(f"  {xi:4.1f}  {mx:.4f}  {mean:.4f}")
