"""Reproduction of paper Table 1 (three experiments × three algorithms).

For each experiment — logistic regression (MNIST-like, RWMH), softmax
classification (CIFAR-like, MALA), robust regression (OPV-like, slice) — we
run Regular MCMC, untuned FlyMC and MAP-tuned FlyMC on synthetic data with
the paper's (N, D, K) shapes, and report the paper's three columns:

    average likelihood queries per iteration  (implementation-independent cost)
    effective samples per 1000 iterations     (min-ESS over θ coordinates)
    speedup relative to regular MCMC          ((ESS/query) ratio)

``--scale`` shrinks N for CPU-budget runs (default 1.0 = paper size for
MNIST/CIFAR; OPV defaults to N=200k — 1.8M with --full).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import diagnostics
from repro.data import logistic_data, robust_data, softmax_data
from repro.models.bayes_glm import GLMModel


@dataclasses.dataclass
class AlgoResult:
    name: str
    queries_per_iter: float
    ess_per_1000: float
    speedup: float
    us_per_iter: float


def _finish(trace, burn):
    """Common post-processing: burn, flatten, ESS, queries/iter, µs/iter."""
    s = np.asarray(trace.theta[0])[burn:]
    if s.ndim == 3:  # softmax: flatten classes
        s = s.reshape(s.shape[0], -1)
    ess = diagnostics.ess_per_1000_iters(s[:, : min(10, s.shape[1])])
    q_per_iter = float(np.asarray(trace.stats.lik_queries[0])[burn:].mean())
    return s, ess, q_per_iter


def _run_flymc(model, kernel, theta0, key, iters, burn, q_db, step0):
    cap = max(256, int(0.05 * model.data.x.shape[0]))
    alg = api.firefly(
        model, kernel=kernel, capacity=cap, cand_capacity=cap, q_db=q_db,
        step_size=step0, adapt_target="auto",
    )
    t0 = time.time()
    trace = api.sample(alg, key, iters, init_position=theta0)
    jax.block_until_ready(trace.theta)
    wall = time.time() - t0
    s, ess, q_per_iter = _finish(trace, burn)
    return s, ess, q_per_iter, wall * 1e6 / iters


def _run_regular(model, kernel, theta0, key, iters, burn, step0):
    alg = api.regular_mcmc(
        model, kernel=kernel, step_size=step0, adapt_target="auto"
    )
    t0 = time.time()
    trace = api.sample(alg, key, iters, init_position=theta0)
    jax.block_until_ready(trace.theta)
    wall = time.time() - t0
    s, ess, q_per_iter = _finish(trace, burn)
    return s, ess, q_per_iter, wall * 1e6 / iters


def run_experiment(
    name: str, model: GLMModel, kernel: str, key, iters: int, burn: int,
    step0: float, q_untuned: float, q_tuned: float, map_steps: int = 400,
) -> list[AlgoResult]:
    d_theta = model.theta_shape
    theta0 = jnp.zeros(d_theta)
    k1, k2, k3, k4 = jax.random.split(key, 4)

    _, ess_r, q_r, us_r = _run_regular(model, kernel, theta0, k1, iters, burn, step0)
    base_eff = ess_r / max(q_r, 1.0)
    results = [AlgoResult(f"{name}/regular", q_r, ess_r, 1.0, us_r)]

    _, ess_u, q_u, us_u = _run_flymc(
        model, kernel, theta0, k2, iters, burn, q_untuned, step0
    )
    results.append(
        AlgoResult(
            f"{name}/flymc-untuned", q_u, ess_u,
            (ess_u / max(q_u, 1.0)) / base_eff, us_u,
        )
    )

    theta_map = model.map_estimate(k3, steps=map_steps)
    tuned = model.map_tuned(theta_map)
    _, ess_t, q_t, us_t = _run_flymc(
        tuned, kernel, theta0, k4, iters, burn, q_tuned, step0
    )
    results.append(
        AlgoResult(
            f"{name}/flymc-MAP-tuned", q_t, ess_t,
            (ess_t / max(q_t, 1.0)) / base_eff, us_t,
        )
    )
    return results


def table1(scale: float = 1.0, iters: int = 3000, burn: int = 750,
           opv_n: int = 200_000, seed: int = 0) -> list[AlgoResult]:
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    out: list[AlgoResult] = []

    # §4.1 — MNIST 7v9 logistic regression, random-walk MH
    n1 = int(12_214 * scale)
    data = logistic_data(k1, n=n1, d=51, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)
    out += run_experiment(
        "mnist-logistic-rwmh", model, "rwmh", k1, iters, burn,
        step0=0.02, q_untuned=0.1, q_tuned=0.01,
    )

    # §4.2 — CIFAR-3 softmax classification, MALA
    n2 = int(18_000 * scale)
    data = softmax_data(k2, n=n2, d=256, k=3)
    model = GLMModel.softmax(data, n_classes=3, prior_scale=1.0)
    out += run_experiment(
        "cifar-softmax-mala", model, "mala", k2, iters, burn,
        step0=0.002, q_untuned=0.1, q_tuned=0.01,
    )

    # §4.3 — OPV robust regression, slice sampling
    n3 = int(opv_n * scale)
    data, _ = robust_data(k3, n=n3, d=57, nu=4.0)
    model = GLMModel.robust(data, nu=4.0, sigma=1.0, prior_scale=1.0)
    out += run_experiment(
        "opv-robust-slice", model, "slice", k3, iters, burn,
        step0=0.05, q_untuned=0.1, q_tuned=0.01,
    )
    return out


def format_results(results: list[AlgoResult]) -> str:
    lines = [
        "| experiment / algorithm | lik. queries/iter | ESS per 1000 iters |"
        " speedup vs regular |",
        "|---|---|---|---|",
    ]
    for r in results:
        lines.append(
            f"| {r.name} | {r.queries_per_iter:,.0f} | {r.ess_per_1000:.2f} |"
            f" {r.speedup:.1f}× |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--iters", type=int, default=3000)
    ap.add_argument("--opv-n", type=int, default=200_000)
    ap.add_argument("--full", action="store_true", help="OPV at paper 1.8M")
    args = ap.parse_args()
    res = table1(
        scale=args.scale, iters=args.iters,
        opv_n=1_800_000 if args.full else args.opv_n,
    )
    print(format_results(res))
    for r in res:
        print(f"{r.name},{r.us_per_iter:.1f},"
              f"q={r.queries_per_iter:.0f};ess={r.ess_per_1000:.2f};"
              f"speedup={r.speedup:.2f}")
