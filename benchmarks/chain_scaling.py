"""Chain-scaling benchmark: vmap-of-kernels vs chain-batched megakernels.

FlyMC's per-step work is O(touched), but the per-step *fixed* cost (launch
overhead, pipeline fill on ≤capacity workloads) is paid per kernel launch —
and `jax.vmap` over chains launches per chain. With the chain axis as a
leading kernel-grid dimension (``repro.kernels.common.chain_batching``),
all chains coalesce into ONE launch per kernel per step, so the marginal
cost of an extra chain is its compute only, not another fixed cost.

Measures the fused FlyMC step (``backend="pallas"`` + ``z_backend="fused"``)
through ``api.sample`` at ``num_chains ∈ {1, 8, 64}`` under both dispatches
and records, per chain count:

  * ``us_per_step``        — wall µs per iteration (all chains together);
  * ``us_per_step_chain``  — ``us_per_step / num_chains``;
  * ``marginal_us_per_chain`` — ``(us(K) − us(1)) / (K − 1)``: what one
    more chain costs. Sublinear scaling ⇔ this sits strictly below the
    1-chain cost.

Off-TPU both paths run the kernels in Pallas interpret mode — relative
scaling shape, not kernel speed — and the record is flagged
(``interpret: true``), same policy as the other kernel benchmarks.
Results merge into ``BENCH_flymc.json`` under ``chain_scaling``.
"""

from __future__ import annotations

import jax

from benchmarks._util import BENCH_PATH, best_of, merge_write, quickstart_problem
from repro import api
from repro.kernels import common

CHAIN_COUNTS = (1, 8, 64)


def bench(n=512, d=21, capacity=64, iters=20, q_db=0.01, reps=3,
          chain_counts=CHAIN_COUNTS):
    interpret = common.default_interpret()
    tuned, positions = quickstart_problem(
        n, d, num_chains=max(chain_counts)
    )
    key = jax.random.key(3)

    record = {
        "problem": {"name": "quickstart-logistic", "n": n, "d": d,
                    "capacity": capacity, "iters": iters, "q_db": q_db,
                    "backend": "pallas", "z_backend": "fused"},
        "interpret": interpret,
    }
    for mode, batched in (("batched", True), ("vmap", False)):
        per_mode = {}
        with common.chain_batching(batched):
            for k in chain_counts:
                # Fresh algorithm per (mode, K): the dispatch flag is read
                # at trace time and the driver's jit cache keys on it, so a
                # new trace per configuration is what makes the comparison
                # honest.
                alg = api.firefly(
                    tuned, kernel="rwmh", capacity=capacity,
                    cand_capacity=capacity, q_db=q_db, step_size=0.03,
                    backend="pallas", z_backend="fused",
                )
                pos = positions[:k] if k > 1 else positions[0]
                run = lambda: api.sample(
                    alg, key, iters, num_chains=k, chunk_size=iters,
                    init_position=pos,
                )
                # Warm up with the timed call itself: the driver's jit
                # cache keys on chunk_size, so only a same-shape run
                # compiles the executable best_of will measure.
                run()
                wall, out = best_of(run, reps=reps)
                assert out.algorithm.spec.capacity == capacity, (
                    "capacity overflow mid-benchmark: both dispatches would "
                    "time a re-run, not a step"
                )
                us_step = wall * 1e6 / iters
                per_mode[str(k)] = {
                    "us_per_step": us_step,
                    "us_per_step_chain": us_step / k,
                }
        base = per_mode[str(chain_counts[0])]["us_per_step"]
        for k in chain_counts[1:]:
            r = per_mode[str(k)]
            r["marginal_us_per_chain"] = (r["us_per_step"] - base) / (k - 1)
            r["sublinear"] = bool(r["marginal_us_per_chain"] < base)
        record[mode] = per_mode
    return record


def main(quick=False):
    record = bench(
        n=512,
        capacity=64,
        iters=8 if quick else 20,
        reps=2 if quick else 3,
    )
    merge_write({"chain_scaling": record})
    tag = " (interpret)" if record["interpret"] else ""
    print(f"chain scaling{tag}: us/step by num_chains")
    print(f"{'chains':>8} {'batched':>12} {'vmap':>12} "
          f"{'batched marg/chain':>20}")
    for k in CHAIN_COUNTS:
        b = record["batched"][str(k)]
        v = record["vmap"][str(k)]
        marg = b.get("marginal_us_per_chain")
        marg_s = "-" if marg is None else f"{marg:.1f}"
        print(f"{k:>8} {b['us_per_step']:>12.1f} {v['us_per_step']:>12.1f} "
              f"{marg_s:>20}")
    print(f"(wrote {BENCH_PATH.name})")
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
