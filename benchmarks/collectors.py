"""Streaming-observable benchmark: collectors-only vs dense FullTrace.

Measures the quickstart problem through ``api.sample`` two ways on the
identical chain (same keys, same algorithm):

  * ``full_trace`` — the default path: dense θ trajectory + per-step stats
    materialized (memory O(iterations));
  * ``streaming`` — OnlineMoments + RHat + BatchMeansESS + QueryBudget
    collectors only: constant memory regardless of iteration count.

Records ``bytes_materialized`` (trace buffers vs collector carries) and the
µs/step collector overhead under the ``collectors`` key of
``BENCH_flymc.json`` (merge-write: other benchmarks own sibling keys).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks._util import BENCH_PATH, best_of, merge_write, quickstart_problem
from repro import api


def _tree_bytes(tree) -> int:
    return int(sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree)))


def bench(n=5000, d=21, iters=2000, chunk_size=256, q_db=0.01):
    tuned = quickstart_problem(n, d)
    # Capacity sized so the bright set never overflows mid-run: both paths
    # then execute the identical chain and the deltas are pure output-path
    # cost, not capacity-growth recompiles.
    alg = api.firefly(
        tuned, kernel="rwmh", capacity=1024, cand_capacity=1024, q_db=q_db,
        step_size=0.03, adapt_target="auto",
    )
    key = jax.random.key(3)
    stream_colls = {
        "moments": api.OnlineMoments(),
        "rhat": api.RHat(),
        "ess": api.BatchMeansESS(),
        "queries": api.QueryBudget(),
    }

    run_full = lambda: api.sample(alg, key, iters, chunk_size=chunk_size)
    run_stream = lambda: api.sample(
        alg, key, iters, chunk_size=chunk_size, collectors=stream_colls
    )
    trace_full = run_full()   # warm-up / compile (and the bytes sample)
    trace_stream = run_stream()

    def us_per_step(fn):
        wall, _ = best_of(fn)
        return wall * 1e6 / iters

    us_full = us_per_step(lambda: run_full().final_state)
    us_stream = us_per_step(lambda: run_stream().final_state)

    # Bytes the output path materializes: dense buffers vs collector carries.
    bytes_full = _tree_bytes(trace_full.theta) + _tree_bytes(trace_full.stats)
    state = trace_full.final_state
    pos_struct, stats_struct = alg.output_structs(state)
    carries = {
        name: col.init(iters, pos_struct, stats_struct)
        for name, col in stream_colls.items()
    }
    bytes_stream = _tree_bytes(carries)

    record = {
        "collectors": {
            "problem": {"name": "quickstart-logistic", "n": n, "d": d,
                        "kernel": "rwmh", "iters": iters, "q_db": q_db},
            "full_trace": {
                "us_per_step": us_full,
                "bytes_materialized": bytes_full,
            },
            "streaming": {
                "collectors": sorted(stream_colls),
                "us_per_step": us_stream,
                "bytes_materialized": bytes_stream,
            },
            # per-step cost of streaming the reductions instead of storing
            # the trajectory (negative: collectors are cheaper than the
            # dense buffer writes + host concat)
            "overhead_us_per_step": us_stream - us_full,
            "bytes_ratio": bytes_full / max(bytes_stream, 1),
            "rhat_streamed": float(trace_stream.results["rhat"]["r_hat"]),
        }
    }
    return record


def main(quick=False):
    record = bench(
        n=1000 if quick else 5000, iters=400 if quick else 2000
    )
    merge_write(record)
    rec = record["collectors"]
    full, stream = rec["full_trace"], rec["streaming"]
    print(f"full trace:  {full['us_per_step']:8.1f} us/step  "
          f"{full['bytes_materialized']:>12,} bytes materialized")
    print(f"streaming:   {stream['us_per_step']:8.1f} us/step  "
          f"{stream['bytes_materialized']:>12,} bytes materialized "
          f"({', '.join(stream['collectors'])})")
    print(f"collector overhead: {rec['overhead_us_per_step']:+.1f} us/step; "
          f"bytes ratio {rec['bytes_ratio']:,.0f}x "
          f"(wrote {BENCH_PATH.name})")
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
