"""Record the static-analysis sweep in BENCH_flymc.json.

Not a timing benchmark: the recorded quantities are the COST FINGERPRINTS
of every registered hot-path jit — per-entry-point eqn counts, worst
RNG/cumsum/gather/scatter sizes, closure-constant bytes, and each rule's
pass/xfail status. A cost-discipline regression (an O(N) primitive
sneaking back into a fused step, a dataset baked in as a const) then shows
up in the perf trajectory next to the timing numbers it would eventually
poison. The sharded entry points additionally record their collective
census (kind@axis -> per-step count) and the derived per-device wire-bytes
model, so communication regressions land in the same trajectory.

    PYTHONPATH=src python -m benchmarks.static_analysis
"""

from __future__ import annotations

from benchmarks._util import merge_write


def main(quick: bool = False) -> dict:
    # The sweep only traces (and lowers, for the donation rule); it is
    # already CI-sized, so quick/full record the same thing.
    del quick
    from repro.analysis import registry

    summary = registry.run_registry()
    record = {
        "problem": {"n": registry.N, "d": registry.D,
                    "capacity": registry.CAPACITY,
                    # the forced mesh the sharded entry points trace under
                    # (AbstractMesh: axis names + sizes, no devices)
                    "data_shards": registry._DATA_SHARDS},
        **summary.to_record(),
    }
    merge_write({"static_analysis": record})
    return record


if __name__ == "__main__":
    rec = main()
    status = "OK" if rec["ok"] else "FAIL"
    print(f"static_analysis: {status} "
          f"({len(rec['entry_points'])} entry points) -> BENCH_flymc.json")
