"""Continuous batching vs sequential per-job sampling (serve headline).

Workload: the shared 8-job heterogeneous mix (``benchmarks._util.job_mix``
— logistic / 2-chain logistic / softmax / robust / ESS-auto-terminated).
Two ways to drain it:

  * **sequential** — one ``api.sample`` call per job, back to back, each
    running its full ``max_samples`` (the pre-serve workflow);
  * **service** — everything submitted to one ``repro.serve.Service``,
    which packs compatible jobs onto shared lane axes and retires the
    converged ones (batch-means ESS past the policy target) early.

Reported into ``BENCH_flymc.json`` under ``"serving"``: total wall-clock
and jobs/sec for both paths (the speedup ratio is the headline), per-job
latency p50/p95 under the service (all jobs submitted at t=0), mean
chain-slot occupancy, and the chain-steps saved by auto-termination
relative to fixed-length runs. Both paths get one untimed warmup pass so
the comparison measures steady-state sampling, not first-compile.

    PYTHONPATH=src python -m benchmarks.serving [--quick]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks._util import job_mix, merge_write

from repro import api
from repro.serve import Service
from repro.serve import job as job_lib


def _sequential(jobs, chunk_size):
    t0 = time.perf_counter()
    out = {}
    for job in jobs:
        alg = job_lib.build_algorithm(job)
        tr = api.sample(
            alg, jax.random.key(job.seed), job.policy.max_samples,
            num_chains=job.num_chains, chunk_size=chunk_size,
            collectors=job.collectors,
        )
        out[job.job_id] = tr.results
    jax.block_until_ready([jax.tree.leaves(r) for r in out.values()])
    return time.perf_counter() - t0, out


def _service(jobs, chunk_size, slot_budget):
    svc = Service(slot_budget=slot_budget, chunk_size=chunk_size)
    done_at: dict[str, float] = {}
    t0 = time.perf_counter()
    for job in jobs:
        svc.submit(job)
    occupancy = []
    while svc.active():
        for u in svc.step():
            if u.done:
                done_at[u.job_id] = time.perf_counter() - t0
        occupancy.append(svc.scheduler.slots_used / svc.scheduler.slot_budget)
    wall = time.perf_counter() - t0
    return wall, svc, done_at, occupancy


def main(quick: bool = False, seed: int = 0) -> dict:
    if quick:
        kw = dict(n=512, d=8, max_samples=96, num_warmup=20)
        chunk_size, budget = 32, 16
    else:
        kw = dict(n=4096, d=16, max_samples=512, num_warmup=100)
        chunk_size, budget = 64, 16
    n_jobs = 8

    # Warmup both paths on the identical shapes (compile), then time.
    _sequential(job_mix(seed, n_jobs, **kw), chunk_size)
    _service(job_mix(seed, n_jobs, **kw), chunk_size, budget)

    seq_jobs = job_mix(seed, n_jobs, **kw)
    seq_wall, seq_results = _sequential(seq_jobs, chunk_size)

    srv_jobs = job_mix(seed, n_jobs, **kw)
    srv_wall, svc, done_at, occupancy = _service(srv_jobs, chunk_size, budget)

    lat = np.array([done_at[j.job_id] for j in srv_jobs])
    fixed_steps = sum(j.policy.max_samples * j.num_chains for j in srv_jobs)
    actual_steps = sum(
        svc.result(j.job_id).committed * j.num_chains for j in srv_jobs
    )

    # Exactness spot check: a fixed-length job's service results are bitwise
    # the sequential run's (auto-terminated jobs stop earlier by design).
    exact = True
    for j in srv_jobs:
        if j.policy.target_rhat is not None or j.policy.min_ess is not None:
            continue
        a = jax.tree.leaves(svc.result(j.job_id).results)
        b = jax.tree.leaves(seq_results[j.job_id])
        exact &= all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(a, b)
        )

    record = {
        "n_jobs": n_jobs,
        "chunk_size": chunk_size,
        "slot_budget": budget,
        "max_samples": kw["max_samples"],
        "quick": quick,
        "sequential": {
            "wall_s": round(seq_wall, 3),
            "jobs_per_s": round(n_jobs / seq_wall, 3),
        },
        "service": {
            "wall_s": round(srv_wall, 3),
            "jobs_per_s": round(n_jobs / srv_wall, 3),
            "latency_p50_s": round(float(np.percentile(lat, 50)), 3),
            "latency_p95_s": round(float(np.percentile(lat, 95)), 3),
            "occupancy_mean": round(float(np.mean(occupancy)), 3),
        },
        "speedup": round(seq_wall / srv_wall, 3),
        "auto_termination": {
            "fixed_chain_steps": fixed_steps,
            "actual_chain_steps": actual_steps,
            "steps_saved_frac": round(1 - actual_steps / fixed_steps, 3),
        },
        "fixed_length_results_bitwise_equal": bool(exact),
    }
    merge_write({"serving": record})
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    rec = main(quick=args.quick)
    print(
        f"serving: sequential {rec['sequential']['wall_s']}s vs service "
        f"{rec['service']['wall_s']}s (speedup {rec['speedup']}x), "
        f"p50 {rec['service']['latency_p50_s']}s "
        f"p95 {rec['service']['latency_p95_s']}s, "
        f"occupancy {rec['service']['occupancy_mean']}, "
        f"auto-termination saved "
        f"{rec['auto_termination']['steps_saved_frac']:.0%} chain-steps, "
        f"bitwise={rec['fixed_length_results_bitwise_equal']}"
    )
