"""Shared benchmark infrastructure: timing and the BENCH_flymc.json contract.

Every benchmark that persists results co-owns top-level keys in one JSON
file at the repo root; :func:`merge_write` is the single place that encodes
the read-merge-write policy so benchmarks never clobber each other's keys.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_flymc.json"


def best_of(fn, reps: int = 3):
    """Best-of-N wall time for ``fn()`` (blocks on the result).

    Timer noise on shared machines exceeds the effects most benchmarks
    resolve, so a single rep is never trusted. Returns (seconds, last out).
    """
    walls = []
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        walls.append(time.perf_counter() - t0)
    return min(walls), out


def merge_write(update: dict, path: Path = BENCH_PATH) -> dict:
    """Merge ``update`` into the benchmark JSON's top level and write it."""
    merged = {}
    if path.exists():
        merged = json.loads(path.read_text())
    merged.update(update)
    path.write_text(json.dumps(merged, indent=2) + "\n")
    return merged


def quickstart_problem(
    n: int, d: int = 21, map_steps: int = 300, num_chains: int | None = None
):
    """The MAP-tuned quickstart logistic model both backend benchmarks time.

    One definition (same seeds, same tuning) so the ``bright_glm_backend``
    and ``z_update_backend`` records in BENCH_flymc.json are measured on the
    identical problem and cannot silently diverge.

    With ``num_chains`` set, also returns deterministic per-chain start
    positions — small MAP-centered jitter with a fixed seed, shaped
    ``(num_chains, d)`` — so every multi-chain benchmark shares one problem
    builder instead of hand-stacking initial states. Returns ``tuned`` when
    ``num_chains is None`` (back-compat), else ``(tuned, positions)``.
    """
    from repro.data import logistic_data
    from repro.models.bayes_glm import GLMModel

    data = logistic_data(jax.random.key(0), n=n, d=d, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)
    theta_map = model.map_estimate(jax.random.key(1), steps=map_steps)
    tuned = model.map_tuned(theta_map)
    if num_chains is None:
        return tuned
    import jax.numpy as jnp

    positions = theta_map[None, :] + 0.02 * jax.random.normal(
        jax.random.key(2), (num_chains, d), dtype=jnp.asarray(theta_map).dtype
    )
    return tuned, positions
