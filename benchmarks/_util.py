"""Shared benchmark infrastructure: timing and the BENCH_flymc.json contract.

Every benchmark that persists results co-owns top-level keys in one JSON
file at the repo root; :func:`merge_write` is the single place that encodes
the read-merge-write policy so benchmarks never clobber each other's keys.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_flymc.json"


def best_of(fn, reps: int = 3):
    """Best-of-N wall time for ``fn()`` (blocks on the result).

    Timer noise on shared machines exceeds the effects most benchmarks
    resolve, so a single rep is never trusted. Returns (seconds, last out).
    """
    walls = []
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        walls.append(time.perf_counter() - t0)
    return min(walls), out


def merge_write(update: dict, path: Path = BENCH_PATH) -> dict:
    """Merge ``update`` into the benchmark JSON's top level and write it."""
    merged = {}
    if path.exists():
        merged = json.loads(path.read_text())
    merged.update(update)
    path.write_text(json.dumps(merged, indent=2) + "\n")
    return merged


def job_mix(seed: int, n_jobs: int = 8, *, n: int = 2048, d: int = 16,
            max_samples: int = 256, num_warmup: int = 100,
            auto_terminate: bool = True, min_ess: float | None = None,
            target_rhat: float | None = None):
    """A deterministic heterogeneous serve workload: ``n_jobs`` jobs cycling
    through (logistic K=1, logistic K=2, softmax, robust, logistic K=2 with
    a convergence auto-termination policy), each on its own dataset.

    The ONE mix definition shared by ``benchmarks/serving.py``,
    ``examples/flymc_serve.py`` and ``tests/test_serve.py`` — the benchmark
    numbers, the example output and the exactness pins are all measured on
    the same workload, so they cannot silently diverge. ``seed`` shifts
    every dataset and chain seed; sizes are keyword-tunable (tests shrink
    them, benchmarks keep the defaults).

    The convergence variant stops on batch-means ESS by default
    (``min_ess = max_samples / 3`` unless given): ESS grows monotonically
    with committed samples, so "enough effective samples" is an honest,
    reachable stopping rule at any workload size — unlike a split-R̂
    target, which short RWMH chains may never reach (pass ``target_rhat``
    to use one anyway). ``auto_terminate=False`` makes every job
    fixed-length (the exactness tests want full-length solo references).
    """
    from repro.api import collectors as collectors_lib
    from repro.data.synthetic import logistic_data, robust_data, softmax_data
    from repro.serve import Job, TerminationPolicy

    fixed = TerminationPolicy(max_samples=max_samples)
    conv_collectors = None
    if auto_terminate:
        if min_ess is None and target_rhat is None:
            min_ess = max(8.0, max_samples / 3)
        conv = TerminationPolicy(
            max_samples=max_samples,
            min_samples=max(2, max_samples // 8),
            target_rhat=target_rhat, min_ess=min_ess, check_every=2,
        )
        if min_ess is not None:
            conv_collectors = lambda: {
                "trace": collectors_lib.FullTrace(),
                "rhat": collectors_lib.RHat(),
                "ess": collectors_lib.BatchMeansESS(),
            }
    else:
        conv = fixed
    capacity = max(32, n // 4)
    jobs = []
    for i in range(n_jobs):
        key = jax.random.key(1000 * seed + i)
        kind = i % 5
        common = dict(seed=seed + i, capacity=capacity,
                      cand_capacity=capacity, num_warmup=num_warmup)
        if kind == 0:
            jobs.append(Job(job_id=f"logistic-{i}", family="logistic",
                            data=logistic_data(key, n=n, d=d),
                            policy=fixed, **common))
        elif kind == 1:
            jobs.append(Job(job_id=f"logistic2c-{i}", family="logistic",
                            data=logistic_data(key, n=n, d=d),
                            num_chains=2, policy=fixed, **common))
        elif kind == 2:
            jobs.append(Job(job_id=f"softmax-{i}", family="softmax",
                            data=softmax_data(key, n=n, d=d, k=3),
                            policy=fixed, **common))
        elif kind == 3:
            data, _ = robust_data(key, n=n, d=d)
            jobs.append(Job(job_id=f"robust-{i}", family="robust",
                            data=data, policy=fixed, **common))
        else:
            jobs.append(Job(
                job_id=f"logistic-conv-{i}", family="logistic",
                data=logistic_data(key, n=n, d=d), num_chains=2,
                policy=conv,
                collectors=(conv_collectors() if conv_collectors else None),
                **common,
            ))
    return jobs


def quickstart_problem(
    n: int, d: int = 21, map_steps: int = 300, num_chains: int | None = None
):
    """The MAP-tuned quickstart logistic model both backend benchmarks time.

    One definition (same seeds, same tuning) so the ``bright_glm_backend``
    and ``z_update_backend`` records in BENCH_flymc.json are measured on the
    identical problem and cannot silently diverge.

    With ``num_chains`` set, also returns deterministic per-chain start
    positions — small MAP-centered jitter with a fixed seed, shaped
    ``(num_chains, d)`` — so every multi-chain benchmark shares one problem
    builder instead of hand-stacking initial states. Returns ``tuned`` when
    ``num_chains is None`` (back-compat), else ``(tuned, positions)``.
    """
    from repro.data import logistic_data
    from repro.models.bayes_glm import GLMModel

    data = logistic_data(jax.random.key(0), n=n, d=d, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)
    theta_map = model.map_estimate(jax.random.key(1), steps=map_steps)
    tuned = model.map_tuned(theta_map)
    if num_chains is None:
        return tuned
    import jax.numpy as jnp

    positions = theta_map[None, :] + 0.02 * jax.random.normal(
        jax.random.key(2), (num_chains, d), dtype=jnp.asarray(theta_map).dtype
    )
    return tuned, positions
