"""θ-update backend benchmark: jnp gather vs fused Pallas bright-GLM kernel.

Times the FlyMC θ-update likelihood evaluation (the paper's O(|bright|·D)
hot path, §3.1) on the quickstart problem two ways:

  * ``backend="jnp"``    — plain XLA: materialize the gathered rows, evaluate
    the bound, mask + reduce;
  * ``backend="pallas"`` — ``kernels/bright_glm``: scalar-prefetched row DMAs
    straight into VMEM tiles, δ and the masked log L̃ reduction fused
    in-kernel.

Reports µs per joint-log-posterior evaluation, µs/step for a full chain
through ``repro.api.sample``, and an analytic HBM-traffic model (bytes per
θ-eval) for each backend. Off-TPU the Pallas numbers are interpret-mode —
correctness-path timings, not kernel speed — and are flagged as such in the
record (``interpret: true``). Results merge into ``BENCH_flymc.json`` under
``bright_glm_backend``.
"""

from __future__ import annotations

import jax

import jax.numpy as jnp

from benchmarks._util import BENCH_PATH, best_of, merge_write, quickstart_problem
from repro import api
from repro.analysis.kernels import derive_traffic
from repro.core import brightness, flymc
from repro.kernels.bright_glm.ops import bright_glm
from repro.kernels.common import default_interpret


def _bytes_model(n: int, d: int, capacity: int) -> dict:
    """Analytic HBM traffic per θ-eval (f32), C = bright capacity.

    jnp: hand model — the gather materializes a (C, D) row matrix (read +
    write), the bound evaluation streams it again, plus θ and the per-row
    t/ξ/δ vectors; XLA's gather pipeline has no BlockSpecs to derive a
    model from. pallas: derived from the kernel's own BlockSpecs, grid and
    DMAs by ``repro.analysis.kernels.derive_traffic`` — the same model the
    ``kernel-bytes`` sweep rule pins — so this record and the static
    analysis cannot drift apart.
    """
    c = capacity
    s, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    (model,) = derive_traffic(
        lambda *a: bright_glm(*a, interpret=True),
        s((n, d), f32), s((n,), f32), s((n,), f32),
        s((c,), i32), s((), i32), s((d,), f32),
    ).values()
    return {
        "jnp": 3 * c * d * 4 + d * 4 + 4 * c * 4,
        "pallas": model["total"],
        "pallas_terms": {
            name: op["bytes"] for name, op in model["per_operand"].items()
        },
    }


def bench(n=5000, d=21, capacity=1024, iters=300, q_db=0.01, reps=3):
    tuned = quickstart_problem(n, d)
    key = jax.random.key(3)
    interpret = default_interpret()

    record = {"problem": {"name": "quickstart-logistic", "n": n, "d": d,
                          "capacity": capacity, "iters": iters, "q_db": q_db}}
    bmodel = _bytes_model(n, d, capacity)

    for backend in ("jnp", "pallas"):
        alg = api.firefly(
            tuned, kernel="rwmh", capacity=capacity, cand_capacity=capacity,
            q_db=q_db, step_size=0.03, adapt_target="auto", backend=backend,
        )
        state = jax.jit(alg.init)(jax.random.key(1), alg.default_position)
        idx, mask = brightness.bright_buffer(state.bright, capacity)
        f = jax.jit(
            flymc.make_joint_logpost(alg.spec, tuned.data, tuned.stats,
                                     idx, mask)
        )
        theta = state.sampler.theta
        n_evals = 50
        f(theta)  # compile
        wall_eval, _ = best_of(
            lambda: [f(theta + 1e-6 * i) for i in range(n_evals)][-1],
            reps=reps,
        )
        us_eval = wall_eval * 1e6 / n_evals

        api.sample(alg, key, 2, chunk_size=2)  # compile chunk
        wall_step, _ = best_of(
            lambda: api.sample(alg, key, iters, chunk_size=iters), reps=reps
        )
        us_step = wall_step * 1e6 / iters

        record[backend] = {
            "us_per_eval": us_eval,
            "us_per_step": us_step,
            "hbm_bytes_per_eval_model": bmodel[backend],
            "interpret": interpret if backend == "pallas" else False,
        }
        if backend == "pallas":
            record[backend]["hbm_bytes_terms"] = bmodel["pallas_terms"]
    # A compiled-vs-interpreted ratio is not a kernel-speed comparison:
    # record it only when the pallas numbers come from a real TPU compile
    # (same null-when-meaningless policy as driver_overhead's
    # host_overhead_ratio).
    record["us_per_step_ratio"] = (
        None if interpret
        else record["jnp"]["us_per_step"] / record["pallas"]["us_per_step"]
    )
    return record


def main(quick=False):
    record = bench(
        n=2000 if quick else 5000,
        capacity=512 if quick else 1024,
        iters=100 if quick else 300,
    )
    merge_write({"bright_glm_backend": record})
    for backend in ("jnp", "pallas"):
        r = record[backend]
        tag = " (interpret)" if r["interpret"] else ""
        print(f"{backend:>6}{tag}: {r['us_per_eval']:9.1f} us/eval  "
              f"{r['us_per_step']:9.1f} us/step  "
              f"~{r['hbm_bytes_per_eval_model']/1e6:.2f} MB HBM/eval")
    ratio = record["us_per_step_ratio"]
    print(f"us/step ratio (jnp/pallas): "
          f"{'n/a (interpret mode — not kernel speed)' if ratio is None else f'{ratio:.2f}x'} "
          f"(wrote {BENCH_PATH.name})")
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
