"""Roofline assembly (brief deliverable g).

Reads the dry-run JSONs (launch.dryrun) and emits the per-(arch × shape)
roofline table for the single-pod mesh:

    compute_s    = HLO dot-FLOPs per device / 197e12
    memory_s     = HLO HBM-traffic per device / 819e9
    collective_s = bf16-corrected collective wire bytes per device / 50e9
    model_vs_hlo = (6·N·D / chips) / HLO_FLOPs   (remat/redundancy waste)

plus the dominant term and a what-would-move-it note. FLOPs/traffic/
collectives come from the trip-count-aware HLO parse (launch.hlo_analysis),
not cost_analysis (which counts while bodies once — see module docs).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--results DIR] [--mesh single]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ARCH_ORDER = [
    "whisper-tiny", "qwen1.5-110b", "stablelm-1.6b", "qwen2-7b",
    "llama3.2-3b", "mixtral-8x7b", "arctic-480b", "recurrentgemma-9b",
    "rwkv6-7b", "llava-next-mistral-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

NOTES = {
    "compute_s": "raise arithmetic intensity (larger per-chip batch, fuse "
    "attention, skip masked SWA blocks)",
    "memory_s": "cut HBM traffic (remat policy, fused CE, bf16 collectives, "
    "time-chunked recurrence)",
    "collective_s": "cut wire bytes (int8 pod grads, overlap gathers with "
    "compute, TP-resident serve weights)",
}


def load(results: Path, mesh: str):
    rows = {}
    for f in sorted(results.glob(f"dryrun_{mesh}_*.json")):
        rec = json.loads(f.read_text())
        rows[(rec["arch"], rec["shape"])] = rec
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    return f"{x*1e3:7.1f}ms"


def table(rows, mesh: str) -> str:
    out = [
        f"### Roofline — {mesh} pod "
        "(v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | compute | memory | collective | dominant | "
        "6ND/HLO | fits 16G | per-dev GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = rows.get((arch, shape))
            if rec is None:
                continue
            if rec["status"] == "skipped":
                out.append(
                    f"| {arch} | {shape} | — | — | — | skipped "
                    f"(full attention @500k) | — | — | — |"
                )
                continue
            if rec["status"] != "ok":
                out.append(
                    f"| {arch} | {shape} | ERROR: {rec['error'][:60]} |"
                )
                continue
            r = rec["roofline"]
            mem = rec["memory"]
            out.append(
                "| {a} | {s} | {c} | {m} | {k} | {dom} | {ratio:.2f} | "
                "{fit} | {gib:.2f} |".format(
                    a=arch,
                    s=shape,
                    c=fmt_s(r["compute_s"]),
                    m=fmt_s(r["memory_s"]),
                    k=fmt_s(r["collective_s"]),
                    dom=r["dominant"].replace("_s", ""),
                    ratio=r["model_vs_hlo_flops"],
                    fit="yes" if mem["fits_16g"] else "NO",
                    gib=mem["per_device_bytes"] / 2**30,
                )
            )
    return "\n".join(out)


def summarize(rows):
    """Pick the three hillclimb cells per the brief."""
    ok = {k: v for k, v in rows.items() if v["status"] == "ok"}

    def frac(rec):
        r = rec["roofline"]
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        return r["compute_s"] / total if total else 0.0

    worst = min(ok.items(), key=lambda kv: frac(kv[1]))
    coll = max(
        ok.items(),
        key=lambda kv: kv[1]["roofline"]["collective_s"]
        / max(kv[1]["roofline"]["compute_s"], 1e-9),
    )
    return {"worst_roofline_fraction": worst[0], "most_collective_bound": coll[0]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="benchmarks/results")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load(Path(args.results), args.mesh)
    print(table(rows, args.mesh))
    print()
    print("hillclimb candidates:", summarize(rows))


if __name__ == "__main__":
    main()
