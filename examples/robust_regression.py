"""Paper §4.3: robust sparse regression with slice sampling (OPV-style).

Student-t likelihood (ν=4), Laplace prior, tangent Gaussian bounds tightened
at a MAP estimate; slice sampling for θ (variable likelihood evaluations per
iteration, exactly the paper's third experiment).

    PYTHONPATH=src python examples/robust_regression.py [--n 50000]

``ROBUST_N`` / ``ROBUST_ITERS`` env vars shrink the problem (CI smoke).
"""

import argparse
import os

import jax
import numpy as np

from repro import api
from repro.data import robust_data
from repro.models.bayes_glm import GLMModel


def main(n=50_000, d=57, iters=800):
    burn = max(1, iters // 4)
    data, theta_true = robust_data(jax.random.key(0), n=n, d=d, nu=4.0)
    model = GLMModel.robust(data, nu=4.0, sigma=1.0, prior_scale=1.0)

    theta_map = model.map_estimate(jax.random.key(1), steps=600, lr=0.02)
    tuned = model.map_tuned(theta_map)

    alg = api.firefly(
        tuned, kernel="slice", capacity=2048, cand_capacity=2048, q_db=0.01,
        step_size=0.05,
    )
    trace = api.sample(
        alg, jax.random.key(2), iters, init_position=theta_map
    )
    s = np.asarray(trace.theta[0])[burn:]
    total_q = int(trace.total_queries)

    rmse = float(np.sqrt(np.mean((s.mean(0) - np.asarray(theta_true)) ** 2)))
    print(f"N={n:,}  posterior-mean RMSE vs true weights: {rmse:.4f}")
    print(f"likelihood queries/iter: {total_q / iters:,.0f} "
          f"(regular slice sampling would be ~{10 * n:,.0f})")
    print(f"avg bright: {np.asarray(trace.stats.n_bright[0])[burn:].mean():,.0f} "
          f"of {n:,}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int,
                    default=int(os.environ.get("ROBUST_N", 50_000)))
    ap.add_argument("--iters", type=int,
                    default=int(os.environ.get("ROBUST_ITERS", 800)))
    args = ap.parse_args()
    main(n=args.n, iters=args.iters)
