"""Exact Bayesian inference over an LM head with FlyMC (DESIGN.md §4).

Takes any assigned backbone (reduced config), freezes it, and runs
MAP-tuned FlyMC with the Böhning softmax bound over the readout — the
paper's CIFAR experiment lifted onto transformer features. Only the bright
token subset pays a likelihood evaluation per iteration.

    PYTHONPATH=src python examples/lm_lastlayer_flymc.py --arch rwkv6-7b
"""

import argparse

import jax
import numpy as np

from repro import api
from repro.configs import get_reduced
from repro.models import transformer as T
from repro.models.lastlayer import lastlayer_glm


def main(arch="llama3.2-3b", batch=32, seq=129, iters=400, burn=100):
    cfg = get_reduced(arch)
    params, specs = T.init_model(cfg, jax.random.key(0))
    k = jax.random.key(1)
    b = {"tokens": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        b["frames"] = 0.1 * jax.random.normal(k, (batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        b["patches"] = 0.1 * jax.random.normal(k, (batch, cfg.patch_positions, cfg.d_model))

    # Posterior concentration drives bound tightness (paper §3.1): enough
    # tokens per head parameter + a moderate prior keep the chain near the
    # MAP tangency point, where the Böhning bound is tight.
    model = lastlayer_glm(params, specs, cfg, b, prior_scale=0.003)
    n = model.data.x.shape[0]
    theta_map = model.map_estimate(jax.random.key(2), steps=300, lr=0.05)
    tuned = model.map_tuned(theta_map)

    alg = api.firefly(
        tuned, kernel="mala", capacity=max(64, n // 4),
        cand_capacity=max(64, n // 4), q_db=0.05, step_size=1e-3,
        adapt_target="auto",
    )
    trace = api.sample(alg, jax.random.key(3), iters, init_position=theta_map)
    total_q = int(trace.total_queries)
    bright = np.asarray(trace.stats.n_bright[0])[burn:].mean()
    print(f"arch={arch}: N={n} tokens, head θ ∈ R^{model.theta_shape}")
    print(f"avg bright tokens: {bright:,.0f}/{n} ({100*bright/n:.1f}%)")
    print(f"likelihood queries/iter: {total_q/iters:,.0f} "
          f"(full-data MALA would be {n:,})")
    print("note: the Böhning gap sums over classes — δ ≈ K/4 · Var(η) per")
    print("token — so at LM vocabulary sizes the bright set only collapses")
    print("under a tightly concentrated posterior (late-stage training /")
    print("huge token counts). The paper's softmax experiment had K=3; this")
    print("demo concentrates via the prior to exhibit the same mechanism.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    args = ap.parse_args()
    main(arch=args.arch)
