"""Batched serving demo: prefill a prompt batch, decode greedily.

Exercises the ring KV cache / recurrent state machinery that decode_32k and
long_500k lower at production scale.

    PYTHONPATH=src python examples/lm_serve.py --arch mixtral-8x7b --gen 24
"""

import argparse

from repro.launch.serve import serve_reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    gen, stats = serve_reduced(
        args.arch, args.batch, args.prompt_len, args.gen
    )
    print(f"generated {gen.shape}; decode {stats['tok_per_s']:.1f} tok/s "
          f"(CPU, jit included)")


if __name__ == "__main__":
    main()
