"""Posterior sampling as a service: one FlyMC engine, many tenants.

Submits the shared heterogeneous workload (``benchmarks._util.job_mix`` —
logistic, 2-chain logistic, softmax, robust, and an ESS-auto-terminated
variant, each on its own dataset) to a ``repro.serve.Service`` and drains
it with continuous batching: compatible jobs are packed onto the chain
axis of one compiled chunk executable, jobs join and leave the batch at
chunk boundaries, converged jobs retire early and free their slots.

Every chunk boundary streams per-job progress (committed samples, peeked
split-R̂) without perturbing the chains; at the end the example ASSERTS
the service's exactness contract in-process — a fixed-length job's trace
is bitwise identical to a solo ``api.sample`` run with the same seed, no
matter what shared the batch with it.

    PYTHONPATH=src python examples/flymc_serve.py

``FLYMC_SERVE_N`` / ``FLYMC_SERVE_SAMPLES`` / ``FLYMC_SERVE_JOBS`` env
vars shrink the workload (CI smoke uses tiny values).
"""

import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks._util import job_mix  # noqa: E402

from repro import api  # noqa: E402
from repro.api import collectors as C  # noqa: E402
from repro.serve import Service  # noqa: E402
from repro.serve import job as job_lib  # noqa: E402

N = int(os.environ.get("FLYMC_SERVE_N", 2048))
SAMPLES = int(os.environ.get("FLYMC_SERVE_SAMPLES", 256))
JOBS = int(os.environ.get("FLYMC_SERVE_JOBS", 8))
D, WARMUP, CHUNK = 16, max(10, SAMPLES // 4), max(8, SAMPLES // 8)


def main():
    jobs = job_mix(0, JOBS, n=N, d=D, max_samples=SAMPLES,
                   num_warmup=WARMUP)
    svc = Service(slot_budget=16, chunk_size=CHUNK)
    handles = {}
    for job in jobs:
        handles[job.job_id] = svc.submit(job, stream=("rhat",))
    total_slots = sum(j.num_chains for j in jobs)
    print(f"submitted {len(jobs)} jobs ({total_slots} chain slots) to a "
          f"{svc.scheduler.slot_budget}-slot service, chunk={CHUNK}")

    t0 = time.perf_counter()

    def show(u):
        r = u.peeks.get("rhat", {}).get("r_hat", float("nan"))
        tag = f"  <- done: {u.reason}" if u.done else ""
        print(f"  [{time.perf_counter() - t0:6.2f}s] {u.job_id:<16} "
              f"{u.committed:>4}/{SAMPLES}  rhat={r:7.3f}{tag}")

    results = svc.run(on_update=show)
    wall = time.perf_counter() - t0

    fixed = [j for j in jobs
             if j.policy.target_rhat is None and j.policy.min_ess is None]
    saved = sum((SAMPLES - results[j.job_id].committed) * j.num_chains
                for j in jobs)
    budget = sum(SAMPLES * j.num_chains for j in jobs)
    print(f"\ndrained {len(jobs)} jobs in {wall:.2f}s "
          f"({len(svc.scheduler.engines)} engines left — all retired); "
          f"auto-termination saved {saved}/{budget} chain-steps "
          f"({saved / budget:.0%})")

    # --- the exactness contract, asserted end-to-end ----------------------
    probe = fixed[0]
    alg = job_lib.build_algorithm(probe)
    solo = api.sample(
        alg, jax.random.key(probe.seed), probe.policy.max_samples,
        num_chains=probe.num_chains, chunk_size=CHUNK,
        collectors={"trace": C.FullTrace(), "rhat": C.RHat()},
    )
    served = results[probe.job_id].results
    for a, b in zip(jax.tree.leaves(served["trace"]),
                    jax.tree.leaves(solo.results["trace"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(f"exactness: {probe.job_id} served bitwise == solo api.sample "
          f"(trace + stats), packed with {total_slots - probe.num_chains} "
          f"neighbor slots")


if __name__ == "__main__":
    main()
