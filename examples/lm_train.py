"""End-to-end LM training (reduced config, CPU-runnable) with checkpointing.

Any assigned architecture works: --arch mixtral-8x7b gives the MoE path,
--arch rwkv6-7b the recurrence path, etc. The same step function, sharded
with shard_map, is what the multi-pod dry-run compiles at production scale.

    PYTHONPATH=src python examples/lm_train.py --arch llama3.2-3b --steps 200
"""

import argparse

from repro.launch.train import train_reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    _, history = train_reduced(
        args.arch, steps=args.steps, ckpt_dir=args.ckpt_dir, peak_lr=1e-3
    )
    drop = history[0] - history[-1]
    print(f"loss {history[0]:.3f} -> {history[-1]:.3f} (drop {drop:.3f})")


if __name__ == "__main__":
    main()
