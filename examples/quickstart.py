"""Quickstart: exact MCMC with subsets of data, in a screenful.

Runs the paper's core demonstration on a synthetic logistic-regression
problem through the ``repro.api`` surface: build a model, get a pure
``(init, step)`` algorithm from ``firefly(...)`` (or ``regular_mcmc(...)``
for the full-data baseline), and hand it to the device-resident ``sample``
driver — same posterior, an order of magnitude fewer likelihood
evaluations, and zero per-iteration host syncs.

The FlyMC run demonstrates streaming observables: warmup runs with NO
output at all (``collectors={}``), then the sampling phase resumes from
``final_state`` with on-device collectors — the printed posterior moments,
split-R̂, and query counts all come from streaming reductions whose memory
does not scale with the iteration count. A FullTrace collector rides along
only to assert the streamed numbers match the offline numpy ones.

    PYTHONPATH=src python examples/quickstart.py

``QUICKSTART_N`` / ``QUICKSTART_ITERS`` env vars shrink the problem (CI
smoke uses tiny values).
"""

import os

import jax
import numpy as np

from repro import api
from repro.core import diagnostics
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

N = int(os.environ.get("QUICKSTART_N", 5000))
ITERS = int(os.environ.get("QUICKSTART_ITERS", 2000))
D, BURN, CHAINS = 21, max(1, ITERS // 4), 2


def main():
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)

    # --- regular MCMC: every iteration evaluates all N likelihoods --------
    baseline = api.regular_mcmc(model, kernel="rwmh", step_size=0.03)
    ref_tr = api.sample(baseline, jax.random.key(1), ITERS)
    ref = np.asarray(ref_tr.theta[0])[BURN:]
    q_reg = float(np.asarray(ref_tr.stats.lik_queries[0])[BURN:].mean())

    # --- FlyMC: MAP-tune the bounds, then sample with a bright subset -----
    theta_map = model.map_estimate(jax.random.key(2), steps=400)
    tuned = model.map_tuned(theta_map)
    alg = api.firefly(
        tuned, kernel="rwmh", capacity=512, cand_capacity=512, q_db=0.01,
        step_size=0.03, adapt_target="auto",
    )
    # Warmup: two chains, nothing collected — the chain state is the output.
    warm = api.sample(alg, jax.random.key(3), BURN, num_chains=CHAINS,
                      collectors={})
    # Sampling phase: resume from the warm state with streaming collectors.
    keep = ITERS - BURN
    tr = api.sample(
        warm.algorithm,  # possibly capacity-grown during warmup
        jax.random.key(4), keep, num_chains=CHAINS,
        init_state=warm.final_state,
        collectors={
            "moments": api.OnlineMoments(),
            "rhat": api.RHat(),
            "queries": api.QueryBudget(),
            "trace": api.FullTrace(),  # offline cross-check only
        },
    )
    mom, rhat = tr.results["moments"], tr.results["rhat"]
    q_fly = tr.results["queries"] / (CHAINS * keep)

    # --- the streamed numbers ARE the offline numbers ---------------------
    off = np.asarray(tr.results["trace"]["theta"], np.float64)  # (C, T, D)
    st = tr.results["trace"]["stats"]
    np.testing.assert_allclose(mom["mean"], off.mean(1), atol=1e-3)
    np.testing.assert_allclose(
        rhat["r_hat"], diagnostics.split_r_hat(off), rtol=1e-4
    )
    assert tr.results["queries"] == int(
        np.asarray(jax.device_get(st.lik_queries), np.int64).sum()
    )

    fly_mean = mom["mean"].mean(0)  # pool equal-length chains
    fly_std = np.sqrt(
        np.stack([np.diag(c) for c in mom["cov"]]).mean(0)
    )
    print(f"posterior mean   |regular - flymc|_max = "
          f"{np.abs(ref.mean(0) - fly_mean).max():.4f}")
    print(f"posterior std    |regular - flymc|_max = "
          f"{np.abs(ref.std(0) - fly_std).max():.4f}")
    print(f"split-Rhat ({CHAINS} chains, streamed): {rhat['r_hat']:.3f}")
    print(f"likelihood queries/iter:  regular {q_reg:,.0f}   "
          f"flymc {q_fly:,.0f}  ({q_reg / q_fly:.1f}x fewer)")
    ess_r = diagnostics.ess_per_1000_iters(ref[:, :5])
    ess_f = diagnostics.ess_per_1000_iters(off[0][:, :5])
    eff = (ess_f / q_fly) / (ess_r / q_reg)
    print(f"ESS/1000 iters:  regular {ess_r:.1f}  flymc {ess_f:.1f}  "
          f"-> speedup per likelihood query: {eff:.1f}x")
    bright = np.asarray(st.n_bright).mean()
    print(f"avg bright points: {bright:,.0f} of N={N} "
          f"({100 * bright / N:.1f}% — the fireflies)")


if __name__ == "__main__":
    main()
