"""Quickstart: exact MCMC with subsets of data, in 50 lines.

Runs the paper's core demonstration on a synthetic logistic-regression
problem through the ``repro.api`` surface: build a model, get a pure
``(init, step)`` algorithm from ``firefly(...)`` (or ``regular_mcmc(...)``
for the full-data baseline), and hand it to the device-resident ``sample``
driver — same posterior, an order of magnitude fewer likelihood
evaluations, and zero per-iteration host syncs.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import diagnostics
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

N, D, ITERS, BURN = 5000, 21, 2000, 500


def main():
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)

    # --- regular MCMC: every iteration evaluates all N likelihoods --------
    baseline = api.regular_mcmc(model, kernel="rwmh", step_size=0.03)
    ref_tr = api.sample(baseline, jax.random.key(1), ITERS)
    ref = np.asarray(ref_tr.theta[0])[BURN:]
    q_reg = float(np.asarray(ref_tr.stats.lik_queries[0])[BURN:].mean())

    # --- FlyMC: MAP-tune the bounds, then sample with a bright subset -----
    theta_map = model.map_estimate(jax.random.key(2), steps=400)
    tuned = model.map_tuned(theta_map)
    alg = api.firefly(
        tuned, kernel="rwmh", capacity=512, cand_capacity=512, q_db=0.01,
        step_size=0.03, adapt_target="auto",
    )
    trace = api.sample(alg, jax.random.key(3), ITERS)
    fly = np.asarray(trace.theta[0])[BURN:]
    q_fly = int(trace.total_queries) / ITERS

    print(f"posterior mean   |regular - flymc|_max = "
          f"{np.abs(ref.mean(0) - fly.mean(0)).max():.4f}")
    print(f"posterior std    |regular - flymc|_max = "
          f"{np.abs(ref.std(0) - fly.std(0)).max():.4f}")
    print(f"likelihood queries/iter:  regular {q_reg:,.0f}   "
          f"flymc {q_fly:,.0f}  ({q_reg / q_fly:.1f}x fewer)")
    ess_r = diagnostics.ess_per_1000_iters(ref[:, :5])
    ess_f = diagnostics.ess_per_1000_iters(fly[:, :5])
    eff = (ess_f / q_fly) / (ess_r / q_reg)
    print(f"ESS/1000 iters:  regular {ess_r:.1f}  flymc {ess_f:.1f}  "
          f"-> speedup per likelihood query: {eff:.1f}x")
    bright = np.asarray(trace.stats.n_bright[0])[BURN:].mean()
    print(f"avg bright points: {bright:,.0f} of N={N} "
          f"({100 * bright / N:.1f}% — the fireflies)")


if __name__ == "__main__":
    main()
