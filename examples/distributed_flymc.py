"""Pod-scale FlyMC on 8 (emulated) devices: the paper's algorithm sharded.

Data rows live on 8 shards; bound sufficient statistics are psum'd once;
each θ-proposal costs one scalar psum; z-resampling is shard-local. The
driver's collectors compose with ``shard_map`` for free: θ and the psum'd
StepStats come out of the sharded step replicated, so the streaming
reductions (posterior moments, split-R̂, exact query accounting) run on
replicated carries with zero extra collectives — the printed numbers come
from the streaming path and are asserted against the offline trace.

Must run in its own process (device count is fixed at first jax import).

    PYTHONPATH=src python examples/distributed_flymc.py

``FLYMC_DIST_N`` / ``FLYMC_DIST_ITERS`` env vars shrink the problem (CI
smoke uses tiny values; N must stay divisible by 8).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import diagnostics
from repro.data import logistic_data
from repro.distributed.flymc_dist import dist_algorithm, shard_data
from repro.models.bayes_glm import GLMModel


def main(
    n=int(os.environ.get("FLYMC_DIST_N", 32_768)),
    d=11,
    iters=int(os.environ.get("FLYMC_DIST_ITERS", 1500)),
):
    burn = max(1, iters // 4)
    mesh = jax.make_mesh(
        (8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
    )
    data = logistic_data(jax.random.key(0), n=n, d=d, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)
    theta_map = model.map_estimate(jax.random.key(1), steps=400)
    tuned = model.map_tuned(theta_map)

    cap = min(256, n // 8)  # capacities are PER-SHARD: at most n_local rows
    alg = dist_algorithm(
        tuned.bound, tuned.log_prior, mesh, shard_data(tuned.data, mesh),
        kernel="rwmh", capacity=cap, cand_capacity=cap, q_db=0.01,
        adapt_target=0.234,
    )
    # Warmup with no output, then stream the sampling phase's observables.
    warm = api.sample(
        alg, jax.random.key(2), burn, init_position=jnp.zeros(d),
        collectors={},
    )
    keep = iters - burn
    trace = api.sample(
        warm.algorithm, jax.random.key(3), keep,
        init_state=warm.final_state,
        collectors={
            "moments": api.OnlineMoments(),
            "rhat": api.RHat(),
            "queries": api.QueryBudget(),
            "trace": api.FullTrace(),  # offline cross-check only
        },
    )
    mom = trace.results["moments"]
    total_q = trace.results["queries"]

    # streamed == offline, on the sharded chain
    off = np.asarray(trace.results["trace"]["theta"], np.float64)
    st = trace.results["trace"]["stats"]
    np.testing.assert_allclose(mom["mean"], off.mean(1), atol=1e-3)
    np.testing.assert_allclose(
        trace.results["rhat"]["r_hat"], diagnostics.split_r_hat(off),
        rtol=1e-4,
    )
    assert total_q == int(
        np.asarray(jax.device_get(st.lik_queries), np.int64).sum()
    )

    print(f"devices: {jax.device_count()}  N={n:,} sharded 8-way")
    print(f"posterior mean (first 4, streamed): "
          f"{np.round(mom['mean'][0][:4], 3)}")
    print(f"split-Rhat (two halves, streamed): "
          f"{trace.results['rhat']['r_hat']:.3f}")
    print(f"queries/iter: {total_q / keep:,.0f}  "
          f"({n / (total_q / keep):.0f}x fewer than full-data MCMC)")


if __name__ == "__main__":
    main()
