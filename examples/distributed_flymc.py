"""Pod-scale FlyMC on 8 (emulated) devices: the paper's algorithm sharded.

Data rows live on 8 shards; bound sufficient statistics are psum'd once;
each θ-proposal costs one scalar psum; z-resampling is shard-local.
Must run in its own process (device count is fixed at first jax import).

    PYTHONPATH=src python examples/distributed_flymc.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.data import logistic_data
from repro.distributed.flymc_dist import dist_algorithm, shard_data
from repro.models.bayes_glm import GLMModel


def main(n=32_768, d=11, iters=1500, burn=400):
    mesh = jax.make_mesh(
        (8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
    )
    data = logistic_data(jax.random.key(0), n=n, d=d, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)
    theta_map = model.map_estimate(jax.random.key(1), steps=400)
    tuned = model.map_tuned(theta_map)

    alg = dist_algorithm(
        tuned.bound, tuned.log_prior, mesh, shard_data(tuned.data, mesh),
        kernel="rwmh", capacity=256, cand_capacity=256, q_db=0.01,
        adapt_target=0.234,
    )
    trace = api.sample(alg, jax.random.key(2), iters, init_position=jnp.zeros(d))
    s = np.asarray(trace.theta[0])[burn:]
    total_q = int(trace.total_queries)
    print(f"devices: {jax.device_count()}  N={n:,} sharded 8-way")
    print(f"posterior mean (first 4): {np.round(s.mean(0)[:4], 3)}")
    print(f"queries/iter: {total_q / iters:,.0f}  "
          f"({n / (total_q / iters):.0f}x fewer than full-data MCMC)")


if __name__ == "__main__":
    main()
